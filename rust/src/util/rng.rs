//! Seedable PRNG + sampling helpers (offline substrate for `rand`).
//!
//! xoshiro256** seeded through SplitMix64 — the standard, well-analyzed
//! combination. Every stochastic component of the repo (workload generator,
//! property tests, samplers) goes through this type so every run is
//! reproducible from a single `u64` seed.

/// xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from the Box-Muller pair
    spare_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the full state.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s, spare_normal: None }
    }

    /// Independent child stream (for per-request / per-domain generators).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free-enough for our n << 2^64 use.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (pair-cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare_normal.take() {
            return v;
        }
        let (u1, u2) = (self.f64().max(1e-300), self.f64());
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Exponential with rate lambda.
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Gamma(shape k, scale 1) — Marsaglia-Tsang for k >= 1, boost for k < 1.
    /// Used to sample Dirichlet expert-affinity priors.
    pub fn gamma(&mut self, k: f64) -> f64 {
        if k < 1.0 {
            let u = self.f64().max(1e-300);
            return self.gamma(k + 1.0) * u.powf(1.0 / k);
        }
        let d = k - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Dirichlet(alpha) sample of length `n` with symmetric concentration.
    pub fn dirichlet_sym(&mut self, alpha: f64, n: usize) -> Vec<f64> {
        let mut v: Vec<f64> = (0..n).map(|_| self.gamma(alpha)).collect();
        let s: f64 = v.iter().sum();
        if s > 0.0 {
            for x in &mut v {
                *x /= s;
            }
        }
        v
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f64 = weights.iter().map(|w| *w as f64).sum();
        if total <= 0.0 {
            return self.below(weights.len());
        }
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= *w as f64;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// `k` distinct indices from [0, n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k.min(n));
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(8);
        let n = 40_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(10);
        for &alpha in &[0.1, 1.0, 8.0] {
            let v = r.dirichlet_sym(alpha, 32);
            let s: f64 = v.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(v.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn dirichlet_concentration_controls_peakedness() {
        let mut r = Rng::new(11);
        let peaked: f64 = (0..50)
            .map(|_| r.dirichlet_sym(0.05, 16).iter().cloned().fold(0.0, f64::max))
            .sum::<f64>()
            / 50.0;
        let flat: f64 = (0..50)
            .map(|_| r.dirichlet_sym(20.0, 16).iter().cloned().fold(0.0, f64::max))
            .sum::<f64>()
            / 50.0;
        assert!(peaked > 2.0 * flat, "peaked={peaked} flat={flat}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(12);
        let w = [0.0f32, 1.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..4000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[2] as f64 / counts[1] as f64;
        assert!((ratio - 3.0).abs() < 0.5, "{ratio}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(13);
        let idx = r.sample_indices(20, 8);
        assert_eq!(idx.len(), 8);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 8);
    }

    #[test]
    fn fork_streams_diverge() {
        let mut r = Rng::new(14);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
