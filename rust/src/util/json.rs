//! Minimal-but-complete JSON: recursive-descent parser + serializer.
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, bools, null). Numbers are kept as f64 — every consumer in this
//! crate (manifest shapes, wire protocol) fits losslessly below 2^53.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors ---------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(v: impl Into<f64>) -> Json {
        Json::Num(v.into())
    }

    pub fn str(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    // ---- accessors ------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — manifest parsing wants loud
    /// failures, not silent Nones.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError {
            msg: format!("missing key '{key}'"),
            offset: 0,
        })
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|v| *v >= 0.0 && v.fract() == 0.0).map(|v| v as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().filter(|v| v.fract() == 0.0).map(|v| v as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Convenience: `[1,2,3]` -> `vec![1,2,3]`.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---- parsing --------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- serialization --------------------------------------------------
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(v) => {
                if v.fract() == 0.0 && v.abs() < 9e15 {
                    out.push_str(&format!("{}", *v as i64));
                } else {
                    out.push_str(&format!("{v}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs: parse the low half if present.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                self.i += 5;
                                if self.b.get(self.i) != Some(&b'\\')
                                    || self.b.get(self.i + 1) != Some(&b'u')
                                {
                                    return Err(self.err("lone high surrogate"));
                                }
                                let hex2 = std::str::from_utf8(
                                    &self.b[self.i + 2..self.i + 6],
                                )
                                .map_err(|_| self.err("bad \\u escape"))?;
                                let lo = u32::from_str_radix(hex2, 16)
                                    .map_err(|_| self.err("bad \\u escape"))?;
                                self.i += 1; // rest advanced below
                                char::from_u32(
                                    0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00),
                                )
                                .ok_or_else(|| self.err("bad surrogate pair"))?
                            } else {
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                            };
                            out.push(c);
                            self.i += 4; // the final hex digits; loop adds 1
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf-8 sequence
                    let start = self.i;
                    let len = utf8_len(self.b[self.i]);
                    self.i += len;
                    if self.i > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"model":{"d":64,"name":"gptoss-mini"},"arr":[1,2.5,true,null,"s"]}"#;
        let v = Json::parse(text).unwrap();
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn roundtrip_escapes_and_unicode() {
        let v = Json::Str("a\"b\\c\nd\té🙂".to_string());
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(
            Json::parse(r#""😂""#).unwrap(),
            Json::Str("😂".into())
        );
    }

    #[test]
    fn errors_are_reported() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn usize_vec() {
        let v = Json::parse("[4, 2, 7]").unwrap();
        assert_eq!(v.as_usize_vec(), Some(vec![4, 2, 7]));
        assert_eq!(Json::parse("[1.5]").unwrap().as_usize_vec(), None);
    }
}
