//! Flag parsing (offline substrate for `clap`): `--key value`, `--key=value`
//! and bare `--flag` booleans, with typed getters and a usage printer.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an explicit iterator (tests) or `std::env::args` (binaries).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|nxt| !nxt.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} wants an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} wants a number, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn kv_styles() {
        let a = parse("serve --preset gptoss-mini --port=7070 --verbose --batch 16");
        assert_eq!(a.positional, vec!["serve"]);
        assert_eq!(a.get("preset"), Some("gptoss-mini"));
        assert_eq!(a.usize_or("port", 0), 7070);
        assert!(a.bool("verbose"));
        assert_eq!(a.usize_or("batch", 0), 16);
        assert_eq!(a.usize_or("missing", 9), 9);
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = parse("--spec");
        assert!(a.bool("spec"));
    }

    #[test]
    fn floats() {
        let a = parse("--beta 0.5");
        assert_eq!(a.f64_or("beta", 1.0), 0.5);
    }
}
