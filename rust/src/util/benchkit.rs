//! Miniature criterion (offline substrate): warmup + timed iterations with
//! mean / p50 / p99 reporting, plus a tiny table printer used by the
//! paper-reproduction benches to emit the same rows the paper's tables show.

use std::hint::black_box;
use std::time::{Duration, Instant};

pub use std::hint::black_box as bb;

#[derive(Debug, Clone)]
pub struct Stats {
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
    pub min: Duration,
}

impl Stats {
    pub fn mean_us(&self) -> f64 {
        self.mean.as_secs_f64() * 1e6
    }
}

impl std::fmt::Display for Stats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mean {:>10.3?}  p50 {:>10.3?}  p99 {:>10.3?}  min {:>10.3?}  ({} iters)",
            self.mean, self.p50, self.p99, self.min, self.iters
        )
    }
}

/// Time `f` with `warmup` throwaway runs followed by `iters` measured runs.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Stats {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort_unstable();
    let total: Duration = samples.iter().sum();
    let stats = Stats {
        iters,
        mean: total / iters as u32,
        p50: samples[iters / 2],
        p99: samples[(iters * 99 / 100).min(iters - 1)],
        min: samples[0],
    };
    println!("{name:<48} {stats}");
    stats
}

/// Time `f` until roughly `budget` wall time is spent (at least 5 iters).
pub fn bench_for<T>(name: &str, budget: Duration, mut f: impl FnMut() -> T) -> Stats {
    // One calibration run decides the iteration count.
    let t0 = Instant::now();
    black_box(f());
    let once = t0.elapsed().max(Duration::from_nanos(100));
    let iters = ((budget.as_secs_f64() / once.as_secs_f64()) as usize).clamp(5, 100_000);
    bench(name, (iters / 10).max(1), iters, f)
}

/// Markdown-ish table printer for paper-reproduction rows.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self, title: &str) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n## {title}");
        let fmt_row = |cells: &[String]| {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            line
        };
        println!("{}", fmt_row(&self.headers));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        println!("{sep}");
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
    }

    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

/// Write bench output under `target/bench-reports/` (best-effort).
pub fn save_report(name: &str, contents: &str) {
    let dir = std::path::Path::new("target/bench-reports");
    let _ = std::fs::create_dir_all(dir);
    let _ = std::fs::write(dir.join(name), contents);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let s = bench("noop", 2, 50, || 1 + 1);
        assert_eq!(s.iters, 50);
        assert!(s.min <= s.p50 && s.p50 <= s.p99);
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new(&["config", "otps", "drop"]);
        t.row(&["(12,1)".into(), "102.3".into(), "-4.17".into()]);
        let csv = t.to_csv();
        assert!(csv.starts_with("config,otps,drop\n"));
        assert!(csv.contains("(12,1),102.3,-4.17"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
