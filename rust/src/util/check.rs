//! Miniature property-testing harness (offline substrate for `proptest`).
//!
//! `forall(seed, cases, gen, prop)` runs `prop` against `cases` random
//! inputs drawn by `gen`. On failure it retries the failing case with a
//! fresh debug formatting and panics with the case index, the per-case seed
//! (so `forall_one` can replay it) and the input.

use super::rng::Rng;
use std::fmt::Debug;

/// Run `prop` on `cases` generated inputs; panic with a replayable seed on
/// the first failure.
pub fn forall<T: Debug>(
    seed: u64,
    cases: usize,
    mut generate: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let case_seed = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(case as u64);
        let mut rng = Rng::new(case_seed);
        let input = generate(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case}/{cases} (replay seed {case_seed}):\n  \
                 reason: {msg}\n  input: {input:#?}"
            );
        }
    }
}

/// Replay a single case by its seed (printed by a failing `forall`).
pub fn forall_one<T: Debug>(
    case_seed: u64,
    mut generate: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(case_seed);
    let input = generate(&mut rng);
    if let Err(msg) = prop(&input) {
        panic!("replayed property failed: {msg}\n  input: {input:#?}");
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        forall(
            1,
            50,
            |r| r.below(100),
            |_| {
                n += 1;
                Ok(())
            },
        );
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_input() {
        forall(2, 100, |r| r.below(10), |&v| {
            if v < 9 {
                Ok(())
            } else {
                Err("hit nine".into())
            }
        });
    }

    #[test]
    fn generation_is_deterministic_per_seed() {
        let mut a = Vec::new();
        forall(3, 10, |r| r.next_u64(), |&v| {
            a.push(v);
            Ok(())
        });
        let mut b = Vec::new();
        forall(3, 10, |r| r.next_u64(), |&v| {
            b.push(v);
            Ok(())
        });
        assert_eq!(a, b);
    }
}
