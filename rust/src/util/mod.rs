//! Offline substrates.
//!
//! The build environment's baked crate registry carries only the `xla`
//! dependency tree — no serde, rand, clap, criterion or proptest. Everything
//! the coordinator needs beyond that is implemented here from scratch:
//!
//! * [`json`]  — a complete JSON parser/serializer (manifest files, the
//!   server wire protocol, metric dumps).
//! * [`rng`]   — a seedable SplitMix64/xoshiro256** PRNG with the sampling
//!   helpers the workload generator needs (normal, Dirichlet-ish, categorical).
//! * [`check`] — a miniature property-testing harness (randomized cases +
//!   failure reporting) used by the selection invariant suites.
//! * [`benchkit`] — a miniature criterion: warmup + timed iterations +
//!   mean/p50/p99 reporting, used by every `cargo bench` target.
//! * [`cli`]   — flag parsing for the launcher binary and examples.
//! * [`fnv`]   — FNV-1a (KV-cache digests, admission class keys).

pub mod benchkit;
pub mod check;
pub mod cli;
pub mod fnv;
pub mod json;
pub mod rng;
