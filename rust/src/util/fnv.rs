//! FNV-1a — the one non-cryptographic hash the tree needs, shared by the
//! KV-cache digests (`model`) and admission's unlabeled-traffic class keys
//! (`coordinator::admission`). 64-bit, byte-at-a-time, deterministic across
//! runs and platforms.

/// Incremental FNV-1a hasher.
pub struct Fnv(u64);

impl Fnv {
    pub fn new() -> Fnv {
        Fnv(0xcbf29ce484222325)
    }

    #[inline]
    pub fn update_bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }

    /// Fold a u32 in, little-endian (token ids).
    #[inline]
    pub fn update_u32(&mut self, v: u32) {
        self.update_bytes(&v.to_le_bytes());
    }

    /// Fold f32s in by bit pattern (cache digests — bit equality, not
    /// numeric equality, is the contract).
    pub fn update_f32s(&mut self, data: &[f32]) {
        for v in data {
            self.update_bytes(&v.to_bits().to_le_bytes());
        }
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv {
    fn default() -> Self {
        Fnv::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_reference_vectors() {
        // Canonical FNV-1a 64-bit test vectors.
        let mut h = Fnv::new();
        assert_eq!(h.finish(), 0xcbf29ce484222325, "offset basis");
        h.update_bytes(b"a");
        assert_eq!(h.finish(), 0xaf63dc4c8601ec8c);
        let mut h = Fnv::new();
        h.update_bytes(b"foobar");
        assert_eq!(h.finish(), 0x85944171f73967e8);
    }

    #[test]
    fn u32_and_f32_feed_the_same_stream() {
        let mut a = Fnv::new();
        a.update_u32(0x3f800000); // bit pattern of 1.0f32
        let mut b = Fnv::new();
        b.update_f32s(&[1.0]);
        assert_eq!(a.finish(), b.finish());
        // order sensitivity
        let mut c = Fnv::new();
        c.update_u32(1);
        c.update_u32(2);
        let mut d = Fnv::new();
        d.update_u32(2);
        d.update_u32(1);
        assert_ne!(c.finish(), d.finish());
    }
}
