//! Threaded TCP serving front-end (JSON-lines protocol) + client library.
//!
//! ## Architecture: continuous batching, stepped
//!
//! Connection threads parse requests and enqueue them with a per-request
//! response channel. A single worker thread owns the model and drives a
//! live [`ServeLoop`]: between every decode step it drains whatever jobs
//! have arrived and submits them to the loop, and each `step()` admits
//! queued requests into free batch slots *before* the next decode/
//! spec-verify cycle. A request that lands one step after a batch started
//! therefore joins mid-flight (the next step) instead of waiting for the
//! whole previous batch to drain, and finished sequences are answered the
//! moment their slot releases — not when the batch completes. This is the
//! production batching the paper's deployment setting assumes: XShare's
//! per-layer selection adapts to whatever the batch composition is *this
//! step*.
//!
//! The old gather-window batch-at-a-time behaviour survives only as the
//! offline path (`Scheduler::run` = submit-all + step-until-done), used by
//! benches and the fidelity harness; `benches/serve_continuous.rs` measures
//! the throughput gap between the two under Poisson arrivals.
//!
//! Every job gets exactly one FINAL reply: parse failures answer with the
//! recovered id, submit-time rejections (bounded-queue backpressure,
//! unservable prompts — see `coordinator::admission::SubmitError`) answer
//! with a coded protocol error (`"code":"queue_full"`, …), and a worker
//! that dies mid-drain answers its in-flight jobs with the cause. A job
//! that opted into `"stream": true` additionally gets a delta frame for
//! every serving step that committed tokens for it (cut straight from
//! `StepOutcome::deltas` — speculative commits arrive several tokens at a
//! time) before that final reply; non-streaming traffic is byte-unchanged.
//!
//! (The baked registry carries no tokio; this server uses std::net +
//! threads, which for a CPU-bound PJRT backend is the honest design anyway —
//! the model worker is serial either way.)

pub mod protocol;

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::config::ServeConfig;
use crate::coordinator::{Request, ServeLoop};
use crate::fleet::Fleet;
use crate::model::MoeModel;
use crate::runtime::{Engine, Manifest};
pub use protocol::{decode_response, Frame, Response};

/// Error payload routed back to the connection thread: optional stable
/// protocol code (e.g. `queue_full`) plus the human-readable message.
#[derive(Debug, Clone)]
pub struct WireError {
    pub code: Option<&'static str>,
    pub msg: String,
}

impl WireError {
    fn plain(msg: impl Into<String>) -> WireError {
        WireError { code: None, msg: msg.into() }
    }
}

/// One worker→connection message. Every job ends with exactly one
/// `Final`; streaming jobs may see any number of `Delta`s first.
#[derive(Debug)]
enum WorkerReply {
    Delta(Vec<u32>),
    Final(std::result::Result<Vec<u32>, WireError>),
}

type Reply = Sender<WorkerReply>;
type Job = (Request, Reply);

/// Handle to a running server.
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    worker_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and start serving the preset at `artifacts_dir` under `cfg`.
    /// `cfg.addr` may use port 0 to pick a free port (tests do).
    ///
    /// PJRT handles are not `Send`, so the worker thread constructs the
    /// engine itself; `start` blocks until the model is loaded (or fails).
    pub fn start_from_dir(artifacts_dir: std::path::PathBuf, cfg: ServeConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr).context("binding server address")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let (job_tx, job_rx) = channel::<Job>();

        let accept_stop = stop.clone();
        let accept_thread = std::thread::spawn(move || {
            accept_loop(listener, job_tx, accept_stop);
        });

        let worker_stop = stop.clone();
        let (ready_tx, ready_rx) = channel::<std::result::Result<(), String>>();
        let worker_thread = if cfg.fleet_replicas > 1 {
            // Fleet tier: N replica serve loops behind the footprint-affine
            // router. The fleet spawns one engine per replica thread; this
            // worker only routes jobs and pumps waves.
            std::thread::spawn(move || {
                match Fleet::from_preset_dir(&artifacts_dir, &cfg) {
                    Ok(fleet) => {
                        let _ = ready_tx.send(Ok(()));
                        fleet_worker_loop(fleet, job_rx, worker_stop);
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("{e:#}")));
                    }
                }
            })
        } else {
            std::thread::spawn(move || {
                let model = Manifest::load(&artifacts_dir)
                    .and_then(Engine::load)
                    .and_then(MoeModel::new);
                match model {
                    Ok(model) => {
                        let _ = ready_tx.send(Ok(()));
                        worker_loop(model, cfg, job_rx, worker_stop);
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("{e:#}")));
                    }
                }
            })
        };
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(msg)) => anyhow::bail!("server worker failed to load model: {msg}"),
            Err(_) => anyhow::bail!("server worker died during startup"),
        }

        Ok(Server {
            addr,
            stop,
            accept_thread: Some(accept_thread),
            worker_thread: Some(worker_thread),
        })
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        if let Some(t) = self.worker_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
    }
}

/// Whether an accept error is transient: the next `accept` may well
/// succeed, so the accept thread must keep going without logging noise.
fn transient_accept_error(kind: std::io::ErrorKind) -> bool {
    matches!(
        kind,
        std::io::ErrorKind::Interrupted
            | std::io::ErrorKind::ConnectionAborted
            | std::io::ErrorKind::ConnectionReset
            | std::io::ErrorKind::TimedOut
    )
}

fn accept_loop(listener: TcpListener, job_tx: Sender<Job>, stop: Arc<AtomicBool>) {
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                let tx = job_tx.clone();
                std::thread::spawn(move || {
                    let _ = connection_loop(stream, tx);
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) if transient_accept_error(e.kind()) => {}
            Err(e) => {
                // Unexpected (EMFILE, ENOBUFS, …) but not a reason to kill
                // the accept thread permanently: log, back off so a
                // persistent failure can't spin the CPU, and retry.
                eprintln!("xshare server: accept error (will retry): {e}");
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

fn connection_loop(stream: TcpStream, job_tx: Sender<Job>) -> Result<()> {
    let peer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut writer = peer;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        match protocol::decode_request(trimmed) {
            Ok(req) => {
                let id = req.id;
                let (tx, rx) = channel();
                if job_tx.send((req, tx)).is_err() {
                    writeln!(writer, "{}", protocol::encode_error(id, "server stopping"))?;
                    return Ok(());
                }
                loop {
                    match rx.recv() {
                        Ok(WorkerReply::Delta(tokens)) => {
                            writeln!(writer, "{}", protocol::encode_delta(id, &tokens))?
                        }
                        Ok(WorkerReply::Final(Ok(tokens))) => {
                            writeln!(writer, "{}", protocol::encode_response(id, &tokens))?;
                            break;
                        }
                        Ok(WorkerReply::Final(Err(e))) => {
                            let line = match e.code {
                                Some(code) => {
                                    protocol::encode_error_coded(id, code, &e.msg)
                                }
                                None => protocol::encode_error(id, &e.msg),
                            };
                            writeln!(writer, "{line}")?;
                            break;
                        }
                        Err(_) => {
                            writeln!(
                                writer,
                                "{}",
                                protocol::encode_error(id, "worker gone")
                            )?;
                            break;
                        }
                    }
                }
            }
            Err(e) => {
                // Best-effort id recovery so the client can correlate the
                // error with the request it sent (a fixed id of 0 made
                // malformed-payload errors unattributable).
                let id = protocol::extract_id(trimmed);
                writeln!(writer, "{}", protocol::encode_error(id, &format!("{e:#}")))?;
            }
        }
    }
}

/// Remap an incoming job onto a worker-unique internal id (clients may
/// collide) and submit it to the live loop. A submit-time rejection (queue
/// backpressure, unservable prompt) is answered immediately with a coded
/// protocol error — every job gets exactly one reply, never silence.
fn submit_job(
    core: &mut ServeLoop<'_>,
    responders: &mut BTreeMap<u64, Responder>,
    next_internal: &mut u64,
    (mut req, tx): Job,
) {
    let internal = *next_internal;
    *next_internal += 1;
    let client_id = req.id;
    let stream = req.stream;
    req.id = internal;
    match core.submit(req) {
        Ok(()) => {
            responders.insert(internal, Responder { tx, stream });
        }
        Err(e) => {
            let e = e.with_id(client_id);
            let _ = tx.send(WorkerReply::Final(Err(WireError {
                code: Some(e.code()),
                msg: e.to_string(),
            })));
        }
    }
}

/// Reply channel plus the job's streaming opt-in.
struct Responder {
    tx: Reply,
    stream: bool,
}

/// Route one step's deltas (streaming jobs only) and final replies.
fn dispatch_outcome(
    responders: &mut BTreeMap<u64, Responder>,
    deltas: &[(u64, Vec<u32>)],
    finished: Vec<(u64, Vec<u32>)>,
) {
    // Deltas first: a request finishing this step still sees its last
    // delta frame before the final reply (frame ordering is pinned by
    // server_integration).
    for (internal, tokens) in deltas {
        if let Some(r) = responders.get(internal) {
            if r.stream {
                let _ = r.tx.send(WorkerReply::Delta(tokens.clone()));
            }
        }
    }
    for (internal, tokens) in finished {
        if let Some(r) = responders.remove(&internal) {
            let _ = r.tx.send(WorkerReply::Final(Ok(tokens)));
        }
    }
}

fn worker_loop(
    mut model: MoeModel,
    cfg: ServeConfig,
    job_rx: Receiver<Job>,
    stop: Arc<AtomicBool>,
) {
    // Outer loop exists only to rebuild the serving core after a step error
    // (model/cache state is suspect at that point); the inner loop is the
    // live continuous-batching loop.
    let mut next_internal: u64 = 0;
    'serve: while !stop.load(Ordering::SeqCst) {
        let mut core = match ServeLoop::new(&mut model, cfg.clone()) {
            Ok(core) => core,
            Err(e) => {
                // Construction failure is config-determined and permanent:
                // reply with the error until shutdown.
                let msg = format!("{e:#}");
                while !stop.load(Ordering::SeqCst) {
                    match job_rx.recv_timeout(Duration::from_millis(50)) {
                        Ok((_, tx)) => {
                            let _ = tx
                                .send(WorkerReply::Final(Err(WireError::plain(msg.clone()))));
                        }
                        Err(RecvTimeoutError::Timeout) => {}
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                }
                return;
            }
        };
        let mut responders: BTreeMap<u64, Responder> = BTreeMap::new();

        loop {
            if stop.load(Ordering::SeqCst) {
                // Graceful shutdown: stop taking new jobs but finish the
                // sequences already submitted (bounded by max_new_tokens),
                // like the old worker finished its current batch.
                while core.has_work() {
                    match core.step() {
                        Ok(outcome) => {
                            dispatch_outcome(
                                &mut responders,
                                &outcome.deltas,
                                outcome.finished,
                            );
                        }
                        Err(e) => {
                            // The drain died: answer every in-flight job
                            // with the cause instead of dropping channels
                            // (a dropped channel reads as "worker gone",
                            // which hides what actually happened).
                            let msg = format!("{e:#}");
                            for (_, r) in std::mem::take(&mut responders) {
                                let _ = r.tx.send(WorkerReply::Final(Err(
                                    WireError::plain(msg.clone()),
                                )));
                            }
                            break;
                        }
                    }
                }
                break 'serve;
            }
            // Idle: block briefly for the next job. Busy: just drain
            // whatever has arrived — admission happens inside step().
            if !core.has_work() {
                match job_rx.recv_timeout(Duration::from_millis(50)) {
                    Ok(job) => {
                        submit_job(&mut core, &mut responders, &mut next_internal, job)
                    }
                    Err(RecvTimeoutError::Timeout) => continue,
                    Err(RecvTimeoutError::Disconnected) => break 'serve,
                }
            }
            while let Ok(job) = job_rx.try_recv() {
                submit_job(&mut core, &mut responders, &mut next_internal, job);
            }

            match core.step() {
                Ok(outcome) => {
                    // Finished sequences return the moment their slot
                    // releases — mid-batch, not at batch completion —
                    // with streaming jobs' delta frames cut per step.
                    dispatch_outcome(&mut responders, &outcome.deltas, outcome.finished);
                    // The worker consumes results here; keep the loop's
                    // run-report accumulators from growing forever.
                    core.discard_finished();
                }
                Err(e) => {
                    let msg = format!("{e:#}");
                    for (_, r) in std::mem::take(&mut responders) {
                        let _ = r.tx.send(WorkerReply::Final(Err(WireError::plain(
                            msg.clone(),
                        ))));
                    }
                    continue 'serve; // rebuild the core
                }
            }
        }
    }
}

/// Fleet-tier sibling of [`submit_job`]: remap the id, route through the
/// fleet. The outer error (no live replica) is as final as a typed
/// rejection — the job still gets exactly one reply.
fn submit_fleet_job(
    fleet: &mut Fleet,
    responders: &mut BTreeMap<u64, Responder>,
    next_internal: &mut u64,
    (mut req, tx): Job,
) {
    let internal = *next_internal;
    *next_internal += 1;
    let client_id = req.id;
    let stream = req.stream;
    req.id = internal;
    match fleet.submit(req) {
        Ok(Ok(_replica)) => {
            responders.insert(internal, Responder { tx, stream });
        }
        Ok(Err(e)) => {
            let e = e.with_id(client_id);
            let _ = tx.send(WorkerReply::Final(Err(WireError {
                code: Some(e.code()),
                msg: e.to_string(),
            })));
        }
        Err(e) => {
            let _ = tx.send(WorkerReply::Final(Err(WireError::plain(format!("{e:#}")))));
        }
    }
}

/// Fleet-tier worker: same job contract as [`worker_loop`] (exactly one
/// final reply per job, streaming deltas per step), but each iteration
/// pumps one step on EVERY live replica. Replica deaths fail over inside
/// [`Fleet::pump`] — in-flight jobs resume on another replica with their
/// streams intact. A fleet-fatal error (no live replica left for rows in
/// flight) answers everything and then serves errors until shutdown:
/// unlike the single-loop worker there is no cheap rebuild of N engines.
fn fleet_worker_loop(mut fleet: Fleet, job_rx: Receiver<Job>, stop: Arc<AtomicBool>) {
    let mut next_internal: u64 = 0;
    let mut responders: BTreeMap<u64, Responder> = BTreeMap::new();
    loop {
        if stop.load(Ordering::SeqCst) {
            // Graceful shutdown: finish in-flight sequences, reject nothing
            // silently.
            while fleet.has_work() {
                match fleet.pump() {
                    Ok(p) => dispatch_outcome(&mut responders, &p.deltas, p.finished),
                    Err(e) => {
                        let msg = format!("{e:#}");
                        for (_, r) in std::mem::take(&mut responders) {
                            let _ = r
                                .tx
                                .send(WorkerReply::Final(Err(WireError::plain(msg.clone()))));
                        }
                        break;
                    }
                }
            }
            return;
        }
        if !fleet.has_work() {
            match job_rx.recv_timeout(Duration::from_millis(50)) {
                Ok(job) => {
                    submit_fleet_job(&mut fleet, &mut responders, &mut next_internal, job)
                }
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => return,
            }
        }
        while let Ok(job) = job_rx.try_recv() {
            submit_fleet_job(&mut fleet, &mut responders, &mut next_internal, job);
        }
        match fleet.pump() {
            Ok(p) => {
                dispatch_outcome(&mut responders, &p.deltas, p.finished);
                fleet.discard_outputs();
            }
            Err(e) => {
                // Fleet-fatal (no live replica): answer everything in
                // flight, then serve the error until shutdown.
                let msg = format!("{e:#}");
                for (_, r) in std::mem::take(&mut responders) {
                    let _ = r.tx.send(WorkerReply::Final(Err(WireError::plain(msg.clone()))));
                }
                while !stop.load(Ordering::SeqCst) {
                    match job_rx.recv_timeout(Duration::from_millis(50)) {
                        Ok((_, tx)) => {
                            let _ = tx.send(WorkerReply::Final(Err(WireError::plain(
                                msg.clone(),
                            ))));
                        }
                        Err(RecvTimeoutError::Timeout) => {}
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                }
                return;
            }
        }
    }
}

/// Blocking client for the JSON-lines protocol.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connecting to server")?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    /// Submit one request and block for its response.
    pub fn generate(&mut self, req: &Request) -> Result<Response> {
        writeln!(self.writer, "{}", protocol::encode_request(req))?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        protocol::decode_response(line.trim())
    }

    /// Submit a streaming request: `on_delta` fires once per delta frame
    /// (in order), and the final reply — whose tokens are the
    /// concatenation of all deltas — is returned. Forces `stream: true`
    /// on the request.
    pub fn generate_stream(
        &mut self,
        req: &Request,
        mut on_delta: impl FnMut(&[u32]),
    ) -> Result<Response> {
        let mut req = req.clone();
        req.stream = true;
        writeln!(self.writer, "{}", protocol::encode_request(&req))?;
        let mut line = String::new();
        loop {
            line.clear();
            if self.reader.read_line(&mut line)? == 0 {
                anyhow::bail!("server closed the connection mid-stream");
            }
            match protocol::decode_frame(line.trim())? {
                protocol::Frame::Delta { tokens, .. } => on_delta(&tokens),
                protocol::Frame::Final(resp) => return Ok(resp),
            }
        }
    }
}
