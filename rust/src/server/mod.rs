//! Threaded TCP serving front-end (JSON-lines protocol) + client library.
//!
//! Architecture: connection threads parse requests and enqueue them with a
//! per-request response channel; a single worker thread owns the model and
//! drains the queue in dynamic batches (up to `batch_size`, with a short
//! gather window — the "goodput" batching the paper's deployment setting
//! assumes), runs the [`Scheduler`] on each batch, and routes results back.
//!
//! (The baked registry carries no tokio; this server uses std::net +
//! threads, which for a CPU-bound PJRT backend is the honest design anyway —
//! the model worker is serial either way.)

pub mod protocol;

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::config::ServeConfig;
use crate::coordinator::{Request, Scheduler};
use crate::model::MoeModel;
use crate::runtime::{Engine, Manifest};
pub use protocol::{decode_response, Response};

type Job = (Request, Sender<std::result::Result<Vec<u32>, String>>);

/// Handle to a running server.
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    worker_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and start serving the preset at `artifacts_dir` under `cfg`.
    /// `cfg.addr` may use port 0 to pick a free port (tests do).
    ///
    /// PJRT handles are not `Send`, so the worker thread constructs the
    /// engine itself; `start` blocks until the model is loaded (or fails).
    pub fn start_from_dir(artifacts_dir: std::path::PathBuf, cfg: ServeConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr).context("binding server address")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let (job_tx, job_rx) = channel::<Job>();

        let accept_stop = stop.clone();
        let accept_thread = std::thread::spawn(move || {
            accept_loop(listener, job_tx, accept_stop);
        });

        let worker_stop = stop.clone();
        let (ready_tx, ready_rx) = channel::<std::result::Result<(), String>>();
        let worker_thread = std::thread::spawn(move || {
            let model = Manifest::load(&artifacts_dir)
                .and_then(Engine::load)
                .and_then(MoeModel::new);
            match model {
                Ok(model) => {
                    let _ = ready_tx.send(Ok(()));
                    worker_loop(model, cfg, job_rx, worker_stop);
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(format!("{e:#}")));
                }
            }
        });
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(msg)) => anyhow::bail!("server worker failed to load model: {msg}"),
            Err(_) => anyhow::bail!("server worker died during startup"),
        }

        Ok(Server {
            addr,
            stop,
            accept_thread: Some(accept_thread),
            worker_thread: Some(worker_thread),
        })
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        if let Some(t) = self.worker_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
    }
}

fn accept_loop(listener: TcpListener, job_tx: Sender<Job>, stop: Arc<AtomicBool>) {
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                let tx = job_tx.clone();
                std::thread::spawn(move || {
                    let _ = connection_loop(stream, tx);
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => break,
        }
    }
}

fn connection_loop(stream: TcpStream, job_tx: Sender<Job>) -> Result<()> {
    let peer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut writer = peer;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        match protocol::decode_request(trimmed) {
            Ok(req) => {
                let id = req.id;
                let (tx, rx) = channel();
                if job_tx.send((req, tx)).is_err() {
                    writeln!(writer, "{}", protocol::encode_error(id, "server stopping"))?;
                    return Ok(());
                }
                match rx.recv() {
                    Ok(Ok(tokens)) => {
                        writeln!(writer, "{}", protocol::encode_response(id, &tokens))?
                    }
                    Ok(Err(msg)) => writeln!(writer, "{}", protocol::encode_error(id, &msg))?,
                    Err(_) => {
                        writeln!(writer, "{}", protocol::encode_error(id, "worker gone"))?
                    }
                }
            }
            Err(e) => {
                writeln!(writer, "{}", protocol::encode_error(0, &format!("{e:#}")))?;
            }
        }
    }
}

fn worker_loop(
    mut model: MoeModel,
    cfg: ServeConfig,
    job_rx: Receiver<Job>,
    stop: Arc<AtomicBool>,
) {
    // Gather window: wait briefly after the first request so concurrent
    // clients coalesce into one batch (dynamic batching).
    let window = Duration::from_millis(20);
    while !stop.load(Ordering::SeqCst) {
        let first = match job_rx.recv_timeout(Duration::from_millis(50)) {
            Ok(j) => j,
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => continue,
            Err(_) => break,
        };
        let mut jobs = vec![first];
        let deadline = std::time::Instant::now() + window;
        while jobs.len() < cfg.batch_size {
            let now = std::time::Instant::now();
            if now >= deadline {
                break;
            }
            match job_rx.recv_timeout(deadline - now) {
                Ok(j) => jobs.push(j),
                Err(_) => break,
            }
        }

        // Remap ids to be unique within the batch (clients may collide).
        let mut requests = Vec::with_capacity(jobs.len());
        let mut responders: BTreeMap<
            u64,
            (u64, Sender<std::result::Result<Vec<u32>, String>>),
        > = BTreeMap::new();
        for (i, (mut req, tx)) in jobs.into_iter().enumerate() {
            let internal = i as u64;
            responders.insert(internal, (req.id, tx));
            req.id = internal;
            requests.push(req);
        }

        let result =
            Scheduler::new(&mut model, cfg.clone()).and_then(|mut s| s.run(requests));
        match result {
            Ok(report) => {
                for (internal, (_, tx)) in responders {
                    let payload = report
                        .outputs
                        .get(&internal)
                        .cloned()
                        .ok_or_else(|| "request lost".to_string());
                    let _ = tx.send(payload);
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for (_, (_, tx)) in responders {
                    let _ = tx.send(Err(msg.clone()));
                }
            }
        }
    }
}

/// Blocking client for the JSON-lines protocol.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connecting to server")?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    /// Submit one request and block for its response.
    pub fn generate(&mut self, req: &Request) -> Result<Response> {
        writeln!(self.writer, "{}", protocol::encode_request(req))?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        protocol::decode_response(line.trim())
    }
}
