//! JSON-lines wire protocol between clients and the serving front-end.
//!
//! Request  : {"id": 7, "prompt": [1,2,3], "max_new_tokens": 16, "domain": "gpqa"}
//! Response : {"id": 7, "tokens": [..], "n_tokens": 16}
//! Error    : {"id": 7, "error": "..."}

use anyhow::{bail, Context, Result};

use crate::coordinator::Request;
use crate::util::json::Json;

pub fn encode_request(req: &Request) -> String {
    Json::obj(vec![
        ("id", Json::num(req.id as f64)),
        ("prompt", Json::arr(req.prompt.iter().map(|&t| Json::num(t as f64)))),
        ("max_new_tokens", Json::num(req.max_new_tokens as f64)),
        ("domain", Json::str(req.domain.clone())),
    ])
    .dump()
}

pub fn decode_request(line: &str) -> Result<Request> {
    let v = Json::parse(line).context("parsing request line")?;
    let id = v.req("id").map_err(anyhow::Error::msg)?.as_i64().context("id")? as u64;
    let prompt: Vec<u32> = v
        .req("prompt")
        .map_err(anyhow::Error::msg)?
        .as_arr()
        .context("prompt must be an array")?
        .iter()
        .map(|t| t.as_usize().map(|u| u as u32).context("prompt token"))
        .collect::<Result<_>>()?;
    if prompt.is_empty() {
        bail!("empty prompt");
    }
    let max_new =
        v.req("max_new_tokens").map_err(anyhow::Error::msg)?.as_usize().context("max_new_tokens")?;
    if max_new == 0 {
        bail!("max_new_tokens must be ≥ 1");
    }
    let mut req = Request::new(id, prompt, max_new);
    if let Some(d) = v.get("domain").and_then(|d| d.as_str()) {
        req.domain = d.to_string();
    }
    Ok(req)
}

/// Best-effort id recovery from a (possibly malformed) request line, so
/// error replies stay correlatable to the request that caused them.
/// Returns 0 when the line is not JSON or carries no usable numeric `id`.
pub fn extract_id(line: &str) -> u64 {
    Json::parse(line)
        .ok()
        .and_then(|v| v.get("id").and_then(|id| id.as_i64()))
        .map(|id| id.max(0) as u64)
        .unwrap_or(0)
}

pub fn encode_response(id: u64, tokens: &[u32]) -> String {
    Json::obj(vec![
        ("id", Json::num(id as f64)),
        ("tokens", Json::arr(tokens.iter().map(|&t| Json::num(t as f64)))),
        ("n_tokens", Json::num(tokens.len() as f64)),
    ])
    .dump()
}

pub fn encode_error(id: u64, msg: &str) -> String {
    Json::obj(vec![("id", Json::num(id as f64)), ("error", Json::str(msg))]).dump()
}

/// Parsed response on the client side.
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<u32>,
}

pub fn decode_response(line: &str) -> Result<Response> {
    let v = Json::parse(line).context("parsing response line")?;
    if let Some(err) = v.get("error").and_then(|e| e.as_str()) {
        bail!("server error: {err}");
    }
    let id = v.req("id").map_err(anyhow::Error::msg)?.as_i64().context("id")? as u64;
    let tokens = v
        .req("tokens")
        .map_err(anyhow::Error::msg)?
        .as_arr()
        .context("tokens")?
        .iter()
        .map(|t| t.as_usize().map(|u| u as u32).context("token"))
        .collect::<Result<_>>()?;
    Ok(Response { id, tokens })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let mut r = Request::new(9, vec![1, 2, 3], 8);
        r.domain = "gpqa".into();
        let line = encode_request(&r);
        let back = decode_request(&line).unwrap();
        assert_eq!(back.id, 9);
        assert_eq!(back.prompt, vec![1, 2, 3]);
        assert_eq!(back.max_new_tokens, 8);
        assert_eq!(back.domain, "gpqa");
    }

    #[test]
    fn response_roundtrip() {
        let line = encode_response(4, &[7, 8]);
        let r = decode_response(&line).unwrap();
        assert_eq!(r, Response { id: 4, tokens: vec![7, 8] });
    }

    #[test]
    fn error_response_propagates() {
        let line = encode_error(4, "boom");
        let err = decode_response(&line).unwrap_err();
        assert!(format!("{err:#}").contains("boom"));
    }

    #[test]
    fn malformed_requests_rejected() {
        assert!(decode_request("{}").is_err());
        assert!(decode_request(r#"{"id":1,"prompt":[],"max_new_tokens":4}"#).is_err());
        assert!(decode_request(r#"{"id":1,"prompt":[1],"max_new_tokens":0}"#).is_err());
        assert!(decode_request("not json").is_err());
    }

    #[test]
    fn extract_id_recovers_from_malformed_payloads() {
        // Valid JSON, invalid request (empty prompt): id must survive.
        assert_eq!(extract_id(r#"{"id":42,"prompt":[],"max_new_tokens":4}"#), 42);
        // Missing fields entirely: still correlatable.
        assert_eq!(extract_id(r#"{"id":7}"#), 7);
        // No id / not JSON / nonsense id: fall back to 0.
        assert_eq!(extract_id(r#"{"prompt":[1]}"#), 0);
        assert_eq!(extract_id("not json"), 0);
        assert_eq!(extract_id(r#"{"id":"seven"}"#), 0);
        // Negative ids clamp rather than wrap.
        assert_eq!(extract_id(r#"{"id":-3}"#), 0);
    }
}
