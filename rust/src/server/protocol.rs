//! JSON-lines wire protocol between clients and the serving front-end.
//!
//! Request  : {"id": 7, "prompt": [1,2,3], "max_new_tokens": 16, "domain": "gpqa",
//!             "priority": 1, "deadline_ms": 250, "stream": true}   (last three optional)
//! Response : {"id": 7, "tokens": [..], "n_tokens": 16}
//! Delta    : {"id": 7, "delta": [..]}          (streaming requests only)
//! Error    : {"id": 7, "error": "...", "code": "queue_full"}   (code optional)
//!
//! Every request that reaches the server gets exactly one FINAL reply line
//! — malformed payloads and submit-time rejections (queue backpressure,
//! over-long prompts) answer with an error carrying the request id and a
//! stable machine-readable `code`, never with silence. A request that
//! opted into `"stream": true` additionally receives zero or more delta
//! frames BEFORE its final reply: one frame per serving step that
//! committed tokens for it (a speculative commit can carry several tokens
//! in one frame), whose concatenation equals the final reply's `tokens`.
//! Non-streaming clients see byte-identical traffic to the pre-streaming
//! protocol.

use anyhow::{bail, Context, Result};

use crate::coordinator::Request;
use crate::util::json::Json;

pub fn encode_request(req: &Request) -> String {
    let mut fields = vec![
        ("id", Json::num(req.id as f64)),
        ("prompt", Json::arr(req.prompt.iter().map(|&t| Json::num(t as f64)))),
        ("max_new_tokens", Json::num(req.max_new_tokens as f64)),
        ("domain", Json::str(req.domain.clone())),
    ];
    if req.priority != 0 {
        fields.push(("priority", Json::num(req.priority as f64)));
    }
    if let Some(ms) = req.deadline_ms {
        fields.push(("deadline_ms", Json::num(ms as f64)));
    }
    if req.stream {
        fields.push(("stream", Json::Bool(true)));
    }
    Json::obj(fields).dump()
}

pub fn decode_request(line: &str) -> Result<Request> {
    let v = Json::parse(line).context("parsing request line")?;
    let id = v.req("id").map_err(anyhow::Error::msg)?.as_i64().context("id")? as u64;
    let prompt: Vec<u32> = v
        .req("prompt")
        .map_err(anyhow::Error::msg)?
        .as_arr()
        .context("prompt must be an array")?
        .iter()
        .map(|t| t.as_usize().map(|u| u as u32).context("prompt token"))
        .collect::<Result<_>>()?;
    if prompt.is_empty() {
        bail!("empty prompt");
    }
    let max_new =
        v.req("max_new_tokens").map_err(anyhow::Error::msg)?.as_usize().context("max_new_tokens")?;
    if max_new == 0 {
        bail!("max_new_tokens must be ≥ 1");
    }
    let mut req = Request::new(id, prompt, max_new);
    if let Some(d) = v.get("domain").and_then(|d| d.as_str()) {
        req.domain = d.to_string();
    }
    if let Some(p) = v.get("priority") {
        let prio = p.as_usize().context("priority")?;
        req.priority = u32::try_from(prio)
            .map_err(|_| anyhow::anyhow!("priority {prio} exceeds u32"))?;
    }
    if let Some(d) = v.get("deadline_ms") {
        let ms = d.as_usize().context("deadline_ms")?;
        if ms == 0 {
            bail!("deadline_ms must be ≥ 1 (omit the field for no deadline)");
        }
        req.deadline_ms = Some(ms as u64);
    }
    if let Some(s) = v.get("stream") {
        req.stream = s.as_bool().context("stream must be a boolean")?;
    }
    Ok(req)
}

/// Best-effort id recovery from a (possibly malformed) request line, so
/// error replies stay correlatable to the request that caused them.
/// Returns 0 when the line is not JSON or carries no usable numeric `id`.
pub fn extract_id(line: &str) -> u64 {
    Json::parse(line)
        .ok()
        .and_then(|v| v.get("id").and_then(|id| id.as_i64()))
        .map(|id| id.max(0) as u64)
        .unwrap_or(0)
}

pub fn encode_response(id: u64, tokens: &[u32]) -> String {
    Json::obj(vec![
        ("id", Json::num(id as f64)),
        ("tokens", Json::arr(tokens.iter().map(|&t| Json::num(t as f64)))),
        ("n_tokens", Json::num(tokens.len() as f64)),
    ])
    .dump()
}

/// One streaming delta frame: the tokens a single serving step committed
/// for this request (speculative commits carry several at once).
pub fn encode_delta(id: u64, tokens: &[u32]) -> String {
    Json::obj(vec![
        ("id", Json::num(id as f64)),
        ("delta", Json::arr(tokens.iter().map(|&t| Json::num(t as f64)))),
    ])
    .dump()
}

pub fn encode_error(id: u64, msg: &str) -> String {
    Json::obj(vec![("id", Json::num(id as f64)), ("error", Json::str(msg))]).dump()
}

/// Error reply with a stable machine-readable code (e.g. `queue_full`) so
/// clients can react to backpressure without parsing prose.
pub fn encode_error_coded(id: u64, code: &str, msg: &str) -> String {
    Json::obj(vec![
        ("id", Json::num(id as f64)),
        ("error", Json::str(msg)),
        ("code", Json::str(code)),
    ])
    .dump()
}

/// Parsed response on the client side.
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<u32>,
}

/// One parsed reply line of a streaming exchange.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Incremental tokens (streaming requests only; ordering pinned by
    /// `server_integration`).
    Delta { id: u64, tokens: Vec<u32> },
    /// The final reply — identical to the non-streaming response line.
    Final(Response),
}

pub fn decode_response(line: &str) -> Result<Response> {
    let v = Json::parse(line).context("parsing response line")?;
    if let Some(err) = v.get("error").and_then(|e| e.as_str()) {
        match v.get("code").and_then(|c| c.as_str()) {
            Some(code) => bail!("server error [{code}]: {err}"),
            None => bail!("server error: {err}"),
        }
    }
    let id = v.req("id").map_err(anyhow::Error::msg)?.as_i64().context("id")? as u64;
    let tokens = v
        .req("tokens")
        .map_err(anyhow::Error::msg)?
        .as_arr()
        .context("tokens")?
        .iter()
        .map(|t| t.as_usize().map(|u| u as u32).context("token"))
        .collect::<Result<_>>()?;
    Ok(Response { id, tokens })
}

/// Decode one reply line of a streaming exchange: a delta frame or the
/// final reply. Error lines fail with the server's message, like
/// [`decode_response`].
pub fn decode_frame(line: &str) -> Result<Frame> {
    let v = Json::parse(line).context("parsing reply line")?;
    if let Some(delta) = v.get("delta") {
        let id =
            v.req("id").map_err(anyhow::Error::msg)?.as_i64().context("id")? as u64;
        let tokens = delta
            .as_arr()
            .context("delta")?
            .iter()
            .map(|t| t.as_usize().map(|u| u as u32).context("delta token"))
            .collect::<Result<_>>()?;
        return Ok(Frame::Delta { id, tokens });
    }
    decode_response(line).map(Frame::Final)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let mut r = Request::new(9, vec![1, 2, 3], 8);
        r.domain = "gpqa".into();
        let line = encode_request(&r);
        let back = decode_request(&line).unwrap();
        assert_eq!(back.id, 9);
        assert_eq!(back.prompt, vec![1, 2, 3]);
        assert_eq!(back.max_new_tokens, 8);
        assert_eq!(back.domain, "gpqa");
        // defaults survive the wire
        assert_eq!(back.priority, 0);
        assert_eq!(back.deadline_ms, None);
    }

    #[test]
    fn priority_and_deadline_roundtrip() {
        let mut r = Request::new(4, vec![1], 2);
        r.priority = 3;
        r.deadline_ms = Some(250);
        let back = decode_request(&encode_request(&r)).unwrap();
        assert_eq!(back.priority, 3);
        assert_eq!(back.deadline_ms, Some(250));
        // omitted fields default; zero deadline is rejected loudly
        let plain = decode_request(r#"{"id":1,"prompt":[1],"max_new_tokens":2}"#).unwrap();
        assert_eq!((plain.priority, plain.deadline_ms), (0, None));
        assert!(decode_request(
            r#"{"id":1,"prompt":[1],"max_new_tokens":2,"deadline_ms":0}"#
        )
        .is_err());
        // an over-wide priority must fail loudly, not wrap to class 0
        assert!(decode_request(
            r#"{"id":1,"prompt":[1],"max_new_tokens":2,"priority":4294967296}"#
        )
        .is_err());
    }

    #[test]
    fn coded_error_reaches_the_client() {
        let line = encode_error_coded(12, "queue_full", "queue full: request 12");
        assert!(line.contains("\"code\""));
        let err = decode_response(&line).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("queue_full"), "{msg}");
        assert!(msg.contains("request 12"), "{msg}");
    }

    #[test]
    fn stream_flag_roundtrip_and_default() {
        let mut r = Request::new(3, vec![1, 2], 4);
        assert!(!decode_request(&encode_request(&r)).unwrap().stream);
        // the flag is OMITTED when false — non-streaming request lines are
        // byte-identical to the pre-streaming protocol
        assert!(!encode_request(&r).contains("stream"));
        r.stream = true;
        let line = encode_request(&r);
        assert!(line.contains("\"stream\":true"), "{line}");
        assert!(decode_request(&line).unwrap().stream);
        assert!(decode_request(
            r#"{"id":1,"prompt":[1],"max_new_tokens":2,"stream":"yes"}"#
        )
        .is_err());
    }

    #[test]
    fn delta_frames_decode_and_finals_pass_through() {
        let d = encode_delta(9, &[4, 5]);
        assert_eq!(
            decode_frame(&d).unwrap(),
            Frame::Delta { id: 9, tokens: vec![4, 5] }
        );
        let f = encode_response(9, &[4, 5, 6]);
        assert_eq!(
            decode_frame(&f).unwrap(),
            Frame::Final(Response { id: 9, tokens: vec![4, 5, 6] })
        );
        assert!(decode_frame(&encode_error(9, "boom")).is_err());
    }

    #[test]
    fn response_roundtrip() {
        let line = encode_response(4, &[7, 8]);
        let r = decode_response(&line).unwrap();
        assert_eq!(r, Response { id: 4, tokens: vec![7, 8] });
    }

    #[test]
    fn error_response_propagates() {
        let line = encode_error(4, "boom");
        let err = decode_response(&line).unwrap_err();
        assert!(format!("{err:#}").contains("boom"));
    }

    #[test]
    fn malformed_requests_rejected() {
        assert!(decode_request("{}").is_err());
        assert!(decode_request(r#"{"id":1,"prompt":[],"max_new_tokens":4}"#).is_err());
        assert!(decode_request(r#"{"id":1,"prompt":[1],"max_new_tokens":0}"#).is_err());
        assert!(decode_request("not json").is_err());
    }

    #[test]
    fn extract_id_recovers_from_malformed_payloads() {
        // Valid JSON, invalid request (empty prompt): id must survive.
        assert_eq!(extract_id(r#"{"id":42,"prompt":[],"max_new_tokens":4}"#), 42);
        // Missing fields entirely: still correlatable.
        assert_eq!(extract_id(r#"{"id":7}"#), 7);
        // No id / not JSON / nonsense id: fall back to 0.
        assert_eq!(extract_id(r#"{"prompt":[1]}"#), 0);
        assert_eq!(extract_id("not json"), 0);
        assert_eq!(extract_id(r#"{"id":"seven"}"#), 0);
        // Negative ids clamp rather than wrap.
        assert_eq!(extract_id(r#"{"id":-3}"#), 0);
    }
}
