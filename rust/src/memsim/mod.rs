//! Memory-hierarchy cost model → simulated decode latency and OTPS.
//!
//! The paper's effect lives in the memory-bandwidth-bound decode regime of
//! H100s: every activated expert's weights must stream from HBM each step,
//! so step latency — and therefore output-tokens-per-second — tracks the
//! *union* of activated experts. This box cannot reproduce that regime
//! natively (CPU PJRT, fp32, interpret-mode kernels), so OTPS is produced by
//! a calibrated analytic model fed with the **exactly measured** per-layer
//! expert activations from the real decode loop (DESIGN.md §3/§4).
//!
//! * [`profiles`] — hardware profiles (H100 SXM, TPU-v4-ish) and cost
//!   geometries of the paper's evaluation models at full scale
//!   (GPT-OSS-120B in MXFP4, DeepSeek-R1 in FP8).
//! * [`decode_cost`] — per-step latency: fixed overheads + weight streaming
//!   (attention & shared + activated experts) + MXU/tensor-core compute,
//!   plus the draft-model and EP variants.

pub mod decode_cost;
pub mod profiles;

pub use decode_cost::{DecodeCostModel, StepBreakdown};
pub use profiles::{CostGeometry, HardwareProfile};
