//! Hardware profiles and full-scale model cost geometries.
//!
//! The *routing decisions* in this repo come from the mini presets (same
//! N/k geometry as the paper's models); the *cost* of a decode step is
//! computed against the paper's models at full scale, so simulated OTPS
//! lands in the same regime the paper reports (85–200 OTPS for GPT-OSS-120B
//! on one H100). Calibration notes live in EXPERIMENTS.md §Calibration.

use anyhow::{bail, Result};

/// An accelerator profile (decode-relevant parameters only).
#[derive(Debug, Clone)]
pub struct HardwareProfile {
    pub name: String,
    /// HBM bandwidth, bytes/s.
    pub hbm_bw: f64,
    /// Dense matmul throughput, FLOP/s (bf16 tensor-core / MXU).
    pub flops: f64,
    /// Per-kernel-launch / per-layer fixed overhead, seconds.
    pub layer_overhead_s: f64,
    /// Per-step scheduler+sampler overhead, seconds.
    pub step_overhead_s: f64,
}

impl HardwareProfile {
    pub fn by_name(name: &str) -> Result<HardwareProfile> {
        match name {
            // H100 SXM5: 3.35 TB/s HBM3, ~990 TFLOPS bf16 dense.
            "h100" => Ok(HardwareProfile {
                name: "h100".into(),
                hbm_bw: 3.35e12,
                flops: 989e12,
                layer_overhead_s: 6e-6,
                step_overhead_s: 150e-6,
            }),
            // TPU v4: 1.2 TB/s HBM2e, 275 TFLOPS bf16 MXU. The Pallas
            // kernel's BlockSpec schedule targets this memory hierarchy.
            "tpuv4" => Ok(HardwareProfile {
                name: "tpuv4".into(),
                hbm_bw: 1.2e12,
                flops: 275e12,
                layer_overhead_s: 10e-6,
                step_overhead_s: 200e-6,
            }),
            other => bail!("unknown hardware profile '{other}' (h100 | tpuv4)"),
        }
    }
}

/// Decode-cost geometry of one evaluation model at full scale.
#[derive(Debug, Clone)]
pub struct CostGeometry {
    pub name: String,
    /// MoE layers.
    pub n_layers: usize,
    /// Routed experts per layer.
    pub n_experts: usize,
    /// Native top-k.
    pub top_k: usize,
    /// Bytes of one routed expert's weights (quantized serving format).
    pub expert_bytes: f64,
    /// Bytes per layer that load regardless of routing: attention weights,
    /// norms, router, shared experts.
    pub dense_bytes_per_layer: f64,
    /// KV-cache bytes read per token per layer (grows with context; fixed
    /// at a representative 2k context here).
    pub kv_bytes_per_token: f64,
    /// FLOPs per token per activated expert (up+down projections ×2).
    pub flops_per_token_expert: f64,
    /// FLOPs per token per layer for attention+dense parts.
    pub flops_per_token_dense: f64,
    /// Draft model: bytes streamed per draft decode step (0 = no draft).
    pub draft_bytes_per_step: f64,
}

impl CostGeometry {
    /// Map an artifact preset to its full-scale cost geometry.
    pub fn for_preset(preset: &str) -> Result<CostGeometry> {
        match preset {
            // GPT-OSS-120B: 36 layers, 128 experts (top-4), d=2880,
            // expert FFN (SwiGLU) ≈ 24.9M params, served in MXFP4
            // (~0.53 B/param incl. scales) ⇒ ~13 MB/expert.
            // Attention+router+norms ≈ 38M params/layer in bf16.
            "gptoss-mini" | "gptoss" => Ok(CostGeometry {
                name: "gpt-oss-120b".into(),
                n_layers: 36,
                n_experts: 128,
                top_k: 4,
                expert_bytes: 13.2e6,
                dense_bytes_per_layer: 76e6,
                kv_bytes_per_token: 2.0 * 2048.0 * 8.0 * 64.0 * 2.0 / 36.0, // GQA, 2k ctx
                flops_per_token_expert: 2.0 * 24.9e6,
                flops_per_token_dense: 2.0 * 38e6,
                // EAGLE-3 head ≈ 1 layer of the target (~1.5 GB bf16 total)
                draft_bytes_per_step: 3.0e9 / 36.0,
            }),
            // DeepSeek-R1: 58 MoE layers, 256 routed experts (top-8) + 1
            // shared, d=7168, expert FFN 2048 (gate/up/down) ≈ 44M params,
            // FP8 serving ⇒ ~44 MB/expert.
            "dsr1-mini" | "dsr1" => Ok(CostGeometry {
                name: "deepseek-r1".into(),
                n_layers: 58,
                n_experts: 256,
                top_k: 8,
                expert_bytes: 44.0e6,
                dense_bytes_per_layer: 190e6, // MLA attn + shared expert (fp8)
                kv_bytes_per_token: 2.0 * 2048.0 * 576.0 / 58.0, // MLA compressed
                flops_per_token_expert: 2.0 * 44.0e6,
                flops_per_token_dense: 2.0 * 95e6,
                draft_bytes_per_step: 0.0,
            }),
            // The tiny test preset costs out at its literal (fp32) size.
            "tiny" => Ok(CostGeometry {
                name: "tiny".into(),
                n_layers: 2,
                n_experts: 8,
                top_k: 2,
                expert_bytes: (16.0 * 32.0 * 2.0) * 4.0,
                dense_bytes_per_layer: 4.0 * 16.0 * 16.0 * 4.0,
                kv_bytes_per_token: 2.0 * 32.0 * 16.0 * 4.0 / 2.0,
                flops_per_token_expert: 2.0 * 2.0 * 16.0 * 32.0 * 2.0,
                flops_per_token_dense: 2.0 * 4.0 * 16.0 * 16.0,
                draft_bytes_per_step: 16.0 * 64.0 * 4.0,
            }),
            other => bail!("no cost geometry for preset '{other}'"),
        }
    }

    /// Bytes streamed for one decode step given per-layer activated-expert
    /// counts (the quantity XShare minimizes).
    pub fn step_bytes(&self, activated_per_layer: &[usize], n_tokens: usize) -> f64 {
        let expert_bytes: f64 =
            activated_per_layer.iter().map(|&a| a as f64 * self.expert_bytes).sum();
        let dense = self.n_layers as f64 * self.dense_bytes_per_layer;
        let kv = self.n_layers as f64 * self.kv_bytes_per_token * n_tokens as f64;
        expert_bytes + dense + kv
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_resolve() {
        assert!(HardwareProfile::by_name("h100").is_ok());
        assert!(HardwareProfile::by_name("tpuv4").is_ok());
        assert!(HardwareProfile::by_name("a100x").is_err());
    }

    #[test]
    fn geometry_matches_paper_models() {
        let g = CostGeometry::for_preset("gptoss-mini").unwrap();
        assert_eq!(g.n_experts, 128);
        assert_eq!(g.top_k, 4);
        // total routed weight bytes ≈ 60 GB (MXFP4 119B-param model)
        let total = g.expert_bytes * (g.n_layers * g.n_experts) as f64;
        assert!((55e9..70e9).contains(&total), "{total}");

        let d = CostGeometry::for_preset("dsr1-mini").unwrap();
        assert_eq!(d.n_experts, 256);
        assert_eq!(d.top_k, 8);
        let total = d.expert_bytes * (d.n_layers * d.n_experts) as f64;
        assert!((580e9..700e9).contains(&total), "{total}"); // ~653 GB fp8
    }

    #[test]
    fn step_bytes_monotone_in_activation() {
        let g = CostGeometry::for_preset("gptoss-mini").unwrap();
        let lo = g.step_bytes(&[20; 36], 16);
        let hi = g.step_bytes(&[90; 36], 16);
        assert!(hi > lo);
        // and the delta is exactly the expert stream
        let want = (90.0 - 20.0) * 36.0 * g.expert_bytes;
        assert!(((hi - lo) - want).abs() < 1.0);
    }
}
