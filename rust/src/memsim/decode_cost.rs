//! Per-step decode latency and OTPS accounting.
//!
//! `target_step_seconds` is the heart: for one forward pass of the target
//! model over `n_tokens` rows, with the measured per-layer activated-expert
//! counts, it charges
//!
//!   Σ_l  layer_overhead + bytes_l / HBM_bw   (memory stream: dominant)
//!   Σ_l  flops_l / flops                      (MXU/tensor-core compute)
//!   step_overhead                             (sampler/scheduler)
//!
//! which is the standard roofline treatment of memory-bound decode: the
//! paper's Figure 7/8 (OTPS vs #activated experts) is a straight consequence
//! of the bytes term.

use super::profiles::{CostGeometry, HardwareProfile};
use crate::ep::{EpCostModel, Placement};
use crate::selection::ExpertSet;

/// Itemized cost of one step (inspectable by benches and the perf pass).
#[derive(Debug, Clone, Default)]
pub struct StepBreakdown {
    pub bytes: f64,
    pub mem_seconds: f64,
    pub compute_seconds: f64,
    pub overhead_seconds: f64,
    pub total_seconds: f64,
}

#[derive(Debug, Clone)]
pub struct DecodeCostModel {
    pub hw: HardwareProfile,
    pub geo: CostGeometry,
}

impl DecodeCostModel {
    pub fn new(hw: HardwareProfile, geo: CostGeometry) -> Self {
        DecodeCostModel { hw, geo }
    }

    /// Latency of one target-model forward over `n_tokens` rows with the
    /// given per-layer activated-expert counts.
    pub fn target_step(&self, activated_per_layer: &[usize], n_tokens: usize) -> StepBreakdown {
        assert_eq!(
            activated_per_layer.len(),
            self.geo.n_layers,
            "activation vector must cover all {} cost layers",
            self.geo.n_layers
        );
        let bytes = self.geo.step_bytes(activated_per_layer, n_tokens);
        let mem = bytes / self.hw.hbm_bw;
        // compute: every token runs its k experts (sparse FLOPs) + dense part
        let flops = n_tokens as f64
            * (self.geo.top_k as f64 * self.geo.flops_per_token_expert
                + self.geo.flops_per_token_dense)
            * self.geo.n_layers as f64;
        let compute = flops / self.hw.flops;
        let overhead =
            self.hw.step_overhead_s + self.geo.n_layers as f64 * self.hw.layer_overhead_s;
        StepBreakdown {
            bytes,
            mem_seconds: mem,
            compute_seconds: compute,
            overhead_seconds: overhead,
            // memory and compute overlap on real hardware; decode is
            // memory-bound so the roofline max applies per layer.
            total_seconds: mem.max(compute) + overhead,
        }
    }

    /// Map the mini preset's per-layer activations onto the full-scale cost
    /// model: the mini model has L_mini layers, the cost geometry L_full;
    /// activations are tiled cyclically (they are statistically homogeneous
    /// across layers — Appendix-style uniform budget m_l = K/L).
    pub fn scale_activations(&self, mini: &[usize]) -> Vec<usize> {
        assert!(!mini.is_empty());
        (0..self.geo.n_layers).map(|l| mini[l % mini.len()]).collect()
    }

    /// One draft-model decode step (speculative decoding).
    pub fn draft_step(&self) -> f64 {
        if self.geo.draft_bytes_per_step == 0.0 {
            return 0.0;
        }
        self.geo.draft_bytes_per_step / self.hw.hbm_bw + self.hw.step_overhead_s * 0.3
    }

    /// Draft-side cost of one ragged speculative cycle, from the TRUE
    /// per-row draft depths. The dense draft is memory-bound: every
    /// batched draft sub-step streams the full draft weights once, so the
    /// **deepest** row sets the stream count and shallower rows ride those
    /// calls for free — per-row compute is negligible next to the weight
    /// stream. These are exactly the padded-batch economics the adaptive
    /// depth controller optimises against: shrinking one row below the max
    /// saves verify activation, not draft streams, until the max itself
    /// drops. Uniform depths reproduce the legacy `L_s × draft_step()`
    /// charge bit-for-bit.
    pub fn draft_cost(&self, depths: &[usize]) -> f64 {
        depths.iter().copied().max().unwrap_or(0) as f64 * self.draft_step()
    }

    /// One EP decode step: per-layer straggler latency from MaxLoad plus
    /// all-to-alls, summed over layers (per-layer selected sets supplied).
    pub fn ep_step(
        &self,
        placement: &Placement,
        selected_per_layer: &[&ExpertSet],
        n_tokens: usize,
        ep_model: &EpCostModel,
    ) -> f64 {
        let toks = ep_model.uniform_tokens(n_tokens, placement.n_gpus());
        // scale mini layers to full-scale layer count cyclically
        let mut total = self.hw.step_overhead_s;
        for l in 0..self.geo.n_layers {
            let sel = selected_per_layer[l % selected_per_layer.len()];
            total += ep_model.layer_latency(placement, sel, &toks)
                + self.geo.dense_bytes_per_layer / self.hw.hbm_bw
                + self.hw.layer_overhead_s;
        }
        total
    }

    /// Convenience: simulated OTPS for a homogeneous run.
    /// `tokens_out` tokens produced over `seconds` of simulated time.
    pub fn otps(tokens_out: usize, seconds: f64) -> f64 {
        if seconds <= 0.0 {
            return 0.0;
        }
        tokens_out as f64 / seconds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> DecodeCostModel {
        DecodeCostModel::new(
            HardwareProfile::by_name("h100").unwrap(),
            CostGeometry::for_preset("gptoss-mini").unwrap(),
        )
    }

    #[test]
    fn step_time_monotone_in_activation() {
        let m = model();
        let lo = m.target_step(&[30; 36], 16).total_seconds;
        let hi = m.target_step(&[100; 36], 16).total_seconds;
        assert!(hi > lo);
    }

    #[test]
    fn decode_regime_is_memory_bound() {
        // The premise of the whole paper: at moderate batch, memory streaming
        // dominates compute during decode.
        let m = model();
        let b = m.target_step(&[99; 36], 16);
        assert!(
            b.mem_seconds > 5.0 * b.compute_seconds,
            "mem {} vs compute {}",
            b.mem_seconds,
            b.compute_seconds
        );
    }

    #[test]
    fn baseline_otps_in_paper_regime() {
        // Sanity calibration: vanilla BS=16 activates ~99/128 experts
        // (E[N_a] formula) → OTPS should land in the paper's ~60-120 band
        // (they report 75-86 baseline OTPS per request-stream at BS=16).
        let m = model();
        let step = m.target_step(&[99; 36], 16).total_seconds;
        let total_otps = 16.0 / step;
        let per_stream = total_otps / 16.0;
        assert!(
            (30.0..300.0).contains(&per_stream),
            "per-stream OTPS {per_stream} outside plausible band"
        );
    }

    #[test]
    fn scale_activations_tiles() {
        let m = model();
        let scaled = m.scale_activations(&[10, 20, 30, 40]);
        assert_eq!(scaled.len(), 36);
        assert_eq!(scaled[0], 10);
        assert_eq!(scaled[5], 20);
    }

    #[test]
    fn draft_step_much_cheaper_than_target() {
        let m = model();
        let target = m.target_step(&[99; 36], 16).total_seconds;
        let draft = m.draft_step();
        assert!(draft < target / 5.0, "draft {draft} vs target {target}");
        assert!(draft > 0.0);
    }

    #[test]
    fn ragged_draft_cost_charged_by_max_depth() {
        let m = model();
        let per_call = m.draft_step();
        // uniform depths reproduce the legacy L_s × draft_step charge
        assert_eq!(m.draft_cost(&[3, 3, 3, 3]), 3.0 * per_call);
        // ragged: the deepest row sets the batched stream count
        assert_eq!(m.draft_cost(&[0, 1, 3, 2]), 3.0 * per_call);
        // shrinking a non-max row saves nothing; shrinking the max does
        assert_eq!(m.draft_cost(&[0, 0, 3, 0]), m.draft_cost(&[3, 3, 3, 3]));
        assert!(m.draft_cost(&[0, 0, 2, 0]) < m.draft_cost(&[0, 0, 3, 0]));
        // no drafting rows → no draft charge
        assert_eq!(m.draft_cost(&[0, 0]), 0.0);
        assert_eq!(m.draft_cost(&[]), 0.0);
    }

    #[test]
    fn otps_helper() {
        assert_eq!(DecodeCostModel::otps(100, 2.0), 50.0);
        assert_eq!(DecodeCostModel::otps(100, 0.0), 0.0);
    }
}
