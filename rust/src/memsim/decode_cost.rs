//! Per-step decode latency and OTPS accounting.
//!
//! `target_step_seconds` is the heart: for one forward pass of the target
//! model over `n_tokens` rows, with the measured per-layer activated-expert
//! counts, it charges
//!
//!   Σ_l  layer_overhead + bytes_l / HBM_bw   (memory stream: dominant)
//!   Σ_l  flops_l / flops                      (MXU/tensor-core compute)
//!   step_overhead                             (sampler/scheduler)
//!
//! which is the standard roofline treatment of memory-bound decode: the
//! paper's Figure 7/8 (OTPS vs #activated experts) is a straight consequence
//! of the bytes term.
//!
//! Since PR 10 these models are **pure pricers**: every public pricing
//! entry point returns a [`Charge`] (an itemized [`StepBreakdown`] tagged
//! with a suggested [`Phase`]) and has no clock side effects. Sim time
//! only advances when `coordinator::ServeLoop` posts the charge through
//! its `cost::Ledger` — the single-writer contract in `cost/mod.rs`.

use super::profiles::{CostGeometry, HardwareProfile};
use crate::cost::{Charge, Phase};
use crate::ep::{EpCostModel, Placement};
use crate::selection::ExpertSet;

/// Itemized cost of one step (inspectable by benches and the perf pass).
#[derive(Debug, Clone, Default)]
pub struct StepBreakdown {
    pub bytes: f64,
    pub mem_seconds: f64,
    pub compute_seconds: f64,
    pub overhead_seconds: f64,
    pub total_seconds: f64,
}

#[derive(Debug, Clone)]
pub struct DecodeCostModel {
    pub hw: HardwareProfile,
    pub geo: CostGeometry,
}

impl DecodeCostModel {
    pub fn new(hw: HardwareProfile, geo: CostGeometry) -> Self {
        DecodeCostModel { hw, geo }
    }

    /// Latency of one target-model forward over `n_tokens` rows with the
    /// given per-layer activated-expert counts. Pure pricer: returns a
    /// [`Charge`] (suggested phase [`Phase::Decode`]); nothing is posted.
    pub fn target_step(&self, activated_per_layer: &[usize], n_tokens: usize) -> Charge {
        Charge::new(
            self.step_breakdown(activated_per_layer, n_tokens),
            Phase::Decode,
        )
    }

    fn step_breakdown(&self, activated_per_layer: &[usize], n_tokens: usize) -> StepBreakdown {
        assert_eq!(
            activated_per_layer.len(),
            self.geo.n_layers,
            "activation vector must cover all {} cost layers",
            self.geo.n_layers
        );
        let bytes = self.geo.step_bytes(activated_per_layer, n_tokens);
        let mem = bytes / self.hw.hbm_bw;
        // compute: every token runs its k experts (sparse FLOPs) + dense part
        let flops = n_tokens as f64
            * (self.geo.top_k as f64 * self.geo.flops_per_token_expert
                + self.geo.flops_per_token_dense)
            * self.geo.n_layers as f64;
        let compute = flops / self.hw.flops;
        let overhead =
            self.hw.step_overhead_s + self.geo.n_layers as f64 * self.hw.layer_overhead_s;
        StepBreakdown {
            bytes,
            mem_seconds: mem,
            compute_seconds: compute,
            overhead_seconds: overhead,
            // memory and compute overlap on real hardware; decode is
            // memory-bound so the roofline max applies per layer.
            total_seconds: mem.max(compute) + overhead,
        }
    }

    /// Map the mini preset's per-layer activations onto the full-scale cost
    /// model: the mini model has L_mini layers, the cost geometry L_full;
    /// activations are tiled cyclically (they are statistically homogeneous
    /// across layers — Appendix-style uniform budget m_l = K/L).
    pub fn scale_activations(&self, mini: &[usize]) -> Vec<usize> {
        assert!(!mini.is_empty());
        (0..self.geo.n_layers).map(|l| mini[l % mini.len()]).collect()
    }

    /// Latency of one **fused prefill wave**: every co-prefilling row's
    /// chunk forward in one serving-step round, charged as a SINGLE pass
    /// over the per-layer UNION of their activated experts and the total
    /// token count. This is the prefill-axis analogue of the amortization
    /// continuous batching gives decode — the per-layer weight stream
    /// loads once for the wave instead of once per row, so the memory
    /// term grows with the union (sublinear in rows when activations
    /// overlap, and even for disjoint rows one shared stream of the
    /// combined set beats N separate full streams' fixed dense bytes and
    /// layer overheads). Charging only; token routing stays row-local and
    /// byte-identical (see the wave contract in `model/moe_model.rs`).
    /// Pure pricer: same roofline as [`DecodeCostModel::target_step`],
    /// suggested phase [`Phase::PrefillWave`].
    pub fn prefill_wave(
        &self,
        activated_union_per_layer: &[usize],
        total_tokens: usize,
    ) -> Charge {
        Charge::new(
            self.step_breakdown(activated_union_per_layer, total_tokens),
            Phase::PrefillWave,
        )
    }

    /// One draft-model decode step (speculative decoding).
    pub fn draft_step(&self) -> f64 {
        if self.geo.draft_bytes_per_step == 0.0 {
            return 0.0;
        }
        self.geo.draft_bytes_per_step / self.hw.hbm_bw + self.hw.step_overhead_s * 0.3
    }

    /// Per-row draft compute for one sub-step: the dense draft runs ~2
    /// FLOPs per weight parameter per token, and its serving weights are
    /// ~2 bytes per parameter, so FLOPs-per-token ≈ bytes-streamed — a
    /// deliberate roofline shortcut that keeps the term proportional
    /// without adding another geometry field.
    fn draft_row_compute(&self) -> f64 {
        self.geo.draft_bytes_per_step / self.hw.flops
    }

    /// Draft-side cost of one ragged speculative cycle, from the TRUE
    /// per-row draft depths. Two terms per batched sub-step `j`:
    ///
    ///  * the **stream**: the full draft weights load once per sub-step,
    ///    so the *deepest* row sets the stream count (`max(depths)`
    ///    sub-steps) and shallower rows ride those calls;
    ///  * the **width**: rows still drafting at sub-step `j`
    ///    (`depths[r] > j`) each add one token of draft compute —
    ///    negligible next to the stream on real hardware, but it makes the
    ///    true per-row depths visible in the ledger.
    ///
    /// These are the padded-batch economics the adaptive depth controller
    /// optimises against: shrinking one row below the max trims only the
    /// (small) width term until the max itself drops and a whole weight
    /// stream disappears. A single row at depth `d` charges exactly what
    /// uniform `[d]` used to: `d × (draft_step() + row compute)`. Pure
    /// pricer: suggested phase [`Phase::SpecDraft`].
    pub fn draft_cost(&self, depths: &[usize]) -> Charge {
        let max_d = depths.iter().copied().max().unwrap_or(0);
        if max_d == 0 {
            return Charge::from_seconds(0.0, Phase::SpecDraft);
        }
        let stream = self.draft_step();
        if stream == 0.0 {
            return Charge::from_seconds(0.0, Phase::SpecDraft); // no draft model shipped
        }
        let mut total = 0.0;
        for j in 0..max_d {
            let width = depths.iter().filter(|&&d| d > j).count();
            total += stream + width as f64 * self.draft_row_compute();
        }
        Charge::from_seconds(total, Phase::SpecDraft)
    }

    /// One EP decode step: per-layer straggler latency from MaxLoad plus
    /// all-to-alls, summed over layers (per-layer selected sets supplied).
    /// Pure pricer: the straggler model doesn't itemize, so the charge's
    /// breakdown carries only `total_seconds` (suggested phase
    /// [`Phase::Decode`]).
    pub fn ep_step(
        &self,
        placement: &Placement,
        selected_per_layer: &[&ExpertSet],
        n_tokens: usize,
        ep_model: &EpCostModel,
    ) -> Charge {
        let toks = crate::ep::uniform_tokens(n_tokens, placement.n_gpus());
        // scale mini layers to full-scale layer count cyclically
        let mut total = self.hw.step_overhead_s;
        for l in 0..self.geo.n_layers {
            let sel = selected_per_layer[l % selected_per_layer.len()];
            total += ep_model.layer_latency(placement, sel, &toks)
                + self.geo.dense_bytes_per_layer / self.hw.hbm_bw
                + self.hw.layer_overhead_s;
        }
        Charge::from_seconds(total, Phase::Decode)
    }

    /// Convenience: simulated OTPS for a homogeneous run.
    /// `tokens_out` tokens produced over `seconds` of simulated time.
    pub fn otps(tokens_out: usize, seconds: f64) -> f64 {
        if seconds <= 0.0 {
            return 0.0;
        }
        tokens_out as f64 / seconds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> DecodeCostModel {
        DecodeCostModel::new(
            HardwareProfile::by_name("h100").unwrap(),
            CostGeometry::for_preset("gptoss-mini").unwrap(),
        )
    }

    #[test]
    fn step_time_monotone_in_activation() {
        let m = model();
        let lo = m.target_step(&[30; 36], 16).seconds();
        let hi = m.target_step(&[100; 36], 16).seconds();
        assert!(hi > lo);
    }

    #[test]
    fn decode_regime_is_memory_bound() {
        // The premise of the whole paper: at moderate batch, memory streaming
        // dominates compute during decode.
        let m = model();
        let c = m.target_step(&[99; 36], 16);
        let b = c.breakdown();
        assert!(
            b.mem_seconds > 5.0 * b.compute_seconds,
            "mem {} vs compute {}",
            b.mem_seconds,
            b.compute_seconds
        );
    }

    #[test]
    fn baseline_otps_in_paper_regime() {
        // Sanity calibration: vanilla BS=16 activates ~99/128 experts
        // (E[N_a] formula) → OTPS should land in the paper's ~60-120 band
        // (they report 75-86 baseline OTPS per request-stream at BS=16).
        let m = model();
        let step = m.target_step(&[99; 36], 16).seconds();
        let total_otps = 16.0 / step;
        let per_stream = total_otps / 16.0;
        assert!(
            (30.0..300.0).contains(&per_stream),
            "per-stream OTPS {per_stream} outside plausible band"
        );
    }

    #[test]
    fn scale_activations_tiles() {
        let m = model();
        let scaled = m.scale_activations(&[10, 20, 30, 40]);
        assert_eq!(scaled.len(), 36);
        assert_eq!(scaled[0], 10);
        assert_eq!(scaled[5], 20);
    }

    #[test]
    fn draft_step_much_cheaper_than_target() {
        let m = model();
        let target = m.target_step(&[99; 36], 16).seconds();
        let draft = m.draft_step();
        assert!(draft < target / 5.0, "draft {draft} vs target {target}");
        assert!(draft > 0.0);
    }

    #[test]
    fn ragged_draft_cost_streams_by_max_depth_computes_by_width() {
        // The corrected semantics (ISSUE 5 satellite): the deepest row
        // still sets the batched weight-stream count, but the WIDTH of
        // each sub-step — rows actually drafting at that depth — now
        // charges per-row compute, so the true per-row depths are visible
        // in the ledger (as ROADMAP always claimed they were).
        let m = model();
        let per_call = m.draft_step();
        // a single drafting row charges the legacy per-stream rate plus
        // one row of compute per sub-step
        let solo3 = m.draft_cost(&[0, 0, 3, 0]).seconds();
        assert!(solo3 >= 3.0 * per_call);
        assert_eq!(
            solo3,
            m.draft_cost(&[3]).seconds(),
            "parked rows charge nothing"
        );
        // stream count is set by the max: equal max depth ⇒ equal stream
        // charge, and the ragged batch costs strictly LESS than uniform
        // because its sub-step widths are smaller (3+2+1 vs 4+4+4 rows)
        let ragged = m.draft_cost(&[0, 1, 3, 2]).seconds();
        let uniform = m.draft_cost(&[3, 3, 3, 3]).seconds();
        assert!(
            ragged < uniform,
            "width-insensitive charge: ragged {ragged} !< uniform {uniform}"
        );
        // …but both stay within one weight stream of each other: width is
        // a compute-side correction, the stream term dominates
        assert!(uniform - ragged < per_call);
        // shrinking the max drops a whole stream — the dominant saving
        assert!(m.draft_cost(&[0, 0, 2, 0]).seconds() < solo3);
        assert!(solo3 - m.draft_cost(&[0, 0, 2, 0]).seconds() > 0.9 * per_call);
        // widening at fixed max adds only the (small) per-row compute
        assert!(uniform > solo3);
        assert!(uniform - solo3 < 0.5 * per_call);
        // no drafting rows → no draft charge
        assert_eq!(m.draft_cost(&[0, 0]).seconds(), 0.0);
        assert_eq!(m.draft_cost(&[]).seconds(), 0.0);
    }

    #[test]
    fn fused_wave_charge_beats_sequential_per_row_charges() {
        // The tentpole lever: one wave over the unioned activations and
        // the summed token count must cost strictly less than charging
        // each row's forward separately — even with fully DISJOINT
        // activations (the union pays the combined expert bytes once,
        // the sequential walk pays dense bytes + layer overheads twice).
        let m = model();
        let row_a = [30usize; 36];
        let row_b = [40usize; 36];
        let union_disjoint = [70usize; 36];
        let seq = m.target_step(&row_a, 8).seconds() + m.target_step(&row_b, 8).seconds();
        let fused = m.prefill_wave(&union_disjoint, 16).seconds();
        assert!(fused < seq, "fused {fused} !< sequential {seq}");

        // overlapping activations amortize even harder: same experts on
        // both rows ⇒ the union streams HALF the expert bytes of the
        // sequential walk on top of the fixed-cost saving
        let union_overlap = [40usize; 36]; // row_b's experts cover row_a's
        let fused_overlap = m.prefill_wave(&union_overlap, 16).seconds();
        assert!(fused_overlap < fused);

        // a solo wave degenerates to exactly the single-row charge
        let solo = m.prefill_wave(&row_a, 8);
        let single = m.target_step(&row_a, 8);
        assert_eq!(solo.seconds(), single.seconds());
        assert_eq!(solo.breakdown().bytes, single.breakdown().bytes);
    }

    #[test]
    fn otps_helper() {
        assert_eq!(DecodeCostModel::otps(100, 2.0), 50.0);
        assert_eq!(DecodeCostModel::otps(100, 0.0), 0.0);
    }
}
