//! Typed view of `artifacts/<preset>/manifest.json` — the contract between
//! `python/compile/aot.py` and this runtime. Parsing is strict: a manifest
//! the rust side only half-understands is a deployment bug.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::tensor::DType;
use crate::util::json::Json;

/// Model geometry (mirrors `python/compile/configs.py::ModelConfig`).
#[derive(Debug, Clone)]
pub struct ModelDims {
    pub name: String,
    pub d_model: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub n_layers: usize,
    pub vocab: usize,
    pub max_seq: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub n_shared: usize,
    pub max_batch: usize,
    pub draft_layers: usize,
    pub draft_d_model: usize,
    pub draft_n_heads: usize,
    pub draft_head_dim: usize,
    pub draft_d_ff: usize,
}

#[derive(Debug, Clone)]
pub struct ParamMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

#[derive(Debug, Clone)]
pub struct ProgramMeta {
    pub file: String,
    pub params: Vec<ParamMeta>,
    pub outputs: Vec<ParamMeta>,
}

#[derive(Debug, Clone)]
pub struct WeightMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub file: String,
}

#[derive(Debug, Clone)]
pub struct SelftestMeta {
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory the manifest was loaded from (file paths are relative).
    pub dir: PathBuf,
    pub model: ModelDims,
    pub programs: BTreeMap<String, ProgramMeta>,
    pub weights: Vec<WeightMeta>,
    pub selftests: BTreeMap<String, SelftestMeta>,
}

fn usize_field(obj: &Json, key: &str) -> Result<usize> {
    obj.req(key)?
        .as_usize()
        .with_context(|| format!("field '{key}' is not a non-negative integer"))
}

fn params_from(arr: &Json) -> Result<Vec<ParamMeta>> {
    arr.as_arr()
        .context("params/outputs not an array")?
        .iter()
        .map(|p| {
            Ok(ParamMeta {
                name: p.req("name")?.as_str().context("param name")?.to_string(),
                shape: p
                    .req("shape")?
                    .as_usize_vec()
                    .context("param shape")?,
                dtype: DType::parse(p.req("dtype")?.as_str().context("param dtype")?)?,
            })
        })
        .collect()
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let root = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;

        let fv = usize_field(&root, "format_version")?;
        if fv != 1 {
            bail!("manifest format_version {fv} unsupported (want 1)");
        }

        let m = root.req("model")?;
        let model = ModelDims {
            name: m.req("name")?.as_str().context("model name")?.to_string(),
            d_model: usize_field(m, "d_model")?,
            n_heads: usize_field(m, "n_heads")?,
            head_dim: usize_field(m, "head_dim")?,
            d_ff: usize_field(m, "d_ff")?,
            n_layers: usize_field(m, "n_layers")?,
            vocab: usize_field(m, "vocab")?,
            max_seq: usize_field(m, "max_seq")?,
            n_experts: usize_field(m, "n_experts")?,
            top_k: usize_field(m, "top_k")?,
            n_shared: usize_field(m, "n_shared")?,
            max_batch: usize_field(m, "max_batch")?,
            draft_layers: usize_field(m, "draft_layers")?,
            draft_d_model: usize_field(m, "draft_d_model")?,
            draft_n_heads: usize_field(m, "draft_n_heads")?,
            draft_head_dim: usize_field(m, "draft_head_dim")?,
            draft_d_ff: usize_field(m, "draft_d_ff")?,
        };

        let mut programs = BTreeMap::new();
        for (name, p) in root.req("programs")?.as_obj().context("programs")? {
            programs.insert(
                name.clone(),
                ProgramMeta {
                    file: p.req("file")?.as_str().context("program file")?.to_string(),
                    params: params_from(p.req("params")?)?,
                    outputs: params_from(p.req("outputs")?)?,
                },
            );
        }
        if programs.is_empty() {
            bail!("manifest has no programs");
        }

        let mut weights = Vec::new();
        for w in root.req("weights")?.as_arr().context("weights")? {
            weights.push(WeightMeta {
                name: w.req("name")?.as_str().context("weight name")?.to_string(),
                shape: w.req("shape")?.as_usize_vec().context("weight shape")?,
                file: w.req("file")?.as_str().context("weight file")?.to_string(),
            });
        }

        let mut selftests = BTreeMap::new();
        if let Some(sts) = root.get("selftests").and_then(|v| v.as_obj()) {
            for (name, st) in sts {
                let strings = |key: &str| -> Result<Vec<String>> {
                    st.req(key)?
                        .as_arr()
                        .context("selftest list")?
                        .iter()
                        .map(|v| Ok(v.as_str().context("selftest path")?.to_string()))
                        .collect()
                };
                selftests.insert(
                    name.clone(),
                    SelftestMeta { inputs: strings("inputs")?, outputs: strings("outputs")? },
                );
            }
        }

        Ok(Manifest { dir: dir.to_path_buf(), model, programs, weights, selftests })
    }

    pub fn program(&self, name: &str) -> Result<&ProgramMeta> {
        self.programs
            .get(name)
            .with_context(|| format!("program '{name}' not in manifest"))
    }

    /// Required core programs for the serving path.
    pub fn validate_serving(&self) -> Result<()> {
        for required in ["embed", "attn_router", "moe_layer", "lm_head"] {
            if !self.programs.contains_key(required) {
                bail!("manifest missing required program '{required}'");
            }
        }
        // weight inventory must cover every layer
        for l in 0..self.model.n_layers {
            for suffix in ["wq", "wk", "wv", "wo", "ln1", "ln2", "wg", "w1", "w2", "ws1", "ws2"] {
                let want = format!("layer{l}.{suffix}");
                if !self.weights.iter().any(|w| w.name == want) {
                    bail!("manifest missing weight '{want}'");
                }
            }
        }
        for global in ["emb", "lnf", "unembed"] {
            if !self.weights.iter().any(|w| w.name == global) {
                bail!("manifest missing weight '{global}'");
            }
        }
        Ok(())
    }

    pub fn has_draft(&self) -> bool {
        self.model.draft_layers > 0 && self.programs.contains_key("draft_step")
    }

    /// Whether the preset ships the chunked-prefill artifact. Optional so
    /// artifacts built before PR 2 keep loading (the serve loop falls back
    /// to one-token prefill and refuses `prefill_chunk > 1`).
    pub fn has_prefill(&self) -> bool {
        self.programs.contains_key("prefill_attn_router")
    }

    /// Chunk positions one `prefill_attn_router` invocation advances. The
    /// chunk is compiled at `max_batch` positions so it can borrow the
    /// batch-shaped embed/moe_layer/lm_head programs unchanged.
    pub fn prefill_chunk_capacity(&self) -> usize {
        self.model.max_batch
    }
}

/// Resolve the artifacts root: `$XSHARE_ARTIFACTS` or `./artifacts`.
pub fn artifacts_root() -> PathBuf {
    std::env::var("XSHARE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "format_version": 1,
      "model": {"name":"t","d_model":4,"n_heads":2,"head_dim":2,"d_ff":8,
        "n_layers":1,"vocab":16,"max_seq":8,"n_experts":4,"top_k":2,
        "n_shared":0,"max_batch":2,"draft_layers":0,"draft_d_model":0,
        "draft_n_heads":0,"draft_head_dim":0,"draft_d_ff":0,"seed":0},
      "programs": {"embed": {"file":"embed.hlo.txt","sha256":"x",
        "params":[{"name":"tokens","shape":[2],"dtype":"i32"}],
        "outputs":[{"name":"hidden","shape":[2,4],"dtype":"f32"}]}},
      "weights": [{"name":"emb","shape":[16,4],"file":"weights/emb.bin","dtype":"f32"}],
      "selftests": {"embed":{"inputs":["selftest/embed.in0.bin"],"outputs":["selftest/embed.out0.bin"]}}
    }"#;

    fn write_mini(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), MINI).unwrap();
    }

    #[test]
    fn parses_mini_manifest() {
        let dir = std::env::temp_dir().join("xshare_manifest_test");
        write_mini(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model.n_experts, 4);
        assert_eq!(m.program("embed").unwrap().params[0].dtype, DType::I32);
        assert_eq!(m.weights[0].shape, vec![16, 4]);
        assert_eq!(m.selftests["embed"].inputs.len(), 1);
        assert!(m.program("nope").is_err());
    }

    #[test]
    fn validate_serving_catches_missing_programs() {
        let dir = std::env::temp_dir().join("xshare_manifest_test2");
        write_mini(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert!(m.validate_serving().is_err()); // no attn_router etc.
    }

    #[test]
    fn missing_file_mentions_make_artifacts() {
        let err = Manifest::load(Path::new("/nonexistent/nowhere")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
