//! Host-side tensors: the typed bridge between rust buffers, weight files
//! and PJRT literals.

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unsupported dtype '{other}'"),
        }
    }
}

/// A dense host tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> HostTensor {
        assert_eq!(data.len(), shape.iter().product::<usize>(), "shape/data mismatch");
        HostTensor::F32 { shape, data }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> HostTensor {
        assert_eq!(data.len(), shape.iter().product::<usize>(), "shape/data mismatch");
        HostTensor::I32 { shape, data }
    }

    pub fn zeros_f32(shape: Vec<usize>) -> HostTensor {
        let n = shape.iter().product();
        HostTensor::F32 { shape, data: vec![0.0; n] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            HostTensor::F32 { .. } => DType::F32,
            HostTensor::I32 { .. } => DType::I32,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    /// Read a raw little-endian `.bin` file with a known shape/dtype.
    pub fn read_bin(path: &std::path::Path, shape: Vec<usize>, dtype: DType) -> Result<HostTensor> {
        let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        let n: usize = shape.iter().product();
        if bytes.len() != n * 4 {
            bail!("{path:?}: {} bytes, expected {} for shape {shape:?}", bytes.len(), n * 4);
        }
        match dtype {
            DType::F32 => {
                let data = bytes
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                Ok(HostTensor::F32 { shape, data })
            }
            DType::I32 => {
                let data = bytes
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                Ok(HostTensor::I32 { shape, data })
            }
        }
    }

    /// Convert to a PJRT literal (host copy; used for per-call inputs).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
            HostTensor::I32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
        };
        Ok(lit)
    }

    /// Convert from a PJRT literal, given the declared shape (tuple leaves
    /// arrive with their own shape; we trust the manifest's).
    pub fn from_literal(lit: &xla::Literal, shape: Vec<usize>, dtype: DType) -> Result<HostTensor> {
        match dtype {
            DType::F32 => {
                let data: Vec<f32> = lit.to_vec()?;
                if data.len() != shape.iter().product::<usize>() {
                    bail!("literal size {} != shape {shape:?}", data.len());
                }
                Ok(HostTensor::F32 { shape, data })
            }
            DType::I32 => {
                let data: Vec<i32> = lit.to_vec()?;
                Ok(HostTensor::I32 { shape, data })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_access() {
        let t = HostTensor::f32(vec![2, 3], vec![1.0; 6]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.dtype(), DType::F32);
        assert_eq!(t.as_f32().unwrap().len(), 6);
        assert!(t.as_i32().is_err());
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn rejects_bad_shape() {
        HostTensor::f32(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn bin_roundtrip() {
        let dir = std::env::temp_dir().join("xshare_tensor_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bin");
        let vals = [1.5f32, -2.0, 3.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&path, bytes).unwrap();
        let t = HostTensor::read_bin(&path, vec![3], DType::F32).unwrap();
        assert_eq!(t.as_f32().unwrap(), &vals);
        // wrong size errors
        assert!(HostTensor::read_bin(&path, vec![4], DType::F32).is_err());
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::parse("f32").unwrap(), DType::F32);
        assert_eq!(DType::parse("i32").unwrap(), DType::I32);
        assert!(DType::parse("f64").is_err());
    }
}
