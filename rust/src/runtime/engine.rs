//! The PJRT execution engine: compiles every HLO artifact once at startup,
//! uploads weights to device-resident buffers once, then serves
//! `execute(program, args)` calls from the decode hot path.
//!
//! Argument binding: each program parameter is fed either a [`Arg::Host`]
//! tensor (dynamic per-call data — hidden states, gates, caches, positions)
//! or a [`Arg::Weight`] reference into the persistent weight buffers. On
//! this CPU PJRT build, outputs come back as a single tuple buffer which we
//! copy to host and decompose; a real accelerator deployment would donate
//! the KV-cache buffers instead (see DESIGN.md §Hardware-Adaptation).

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use super::manifest::{Manifest, ProgramMeta};
use super::tensor::{DType, HostTensor};

/// One bound argument for a program call.
pub enum Arg<'a> {
    /// Dynamic host data, uploaded for this call.
    Host(&'a HostTensor),
    /// Named persistent weight (uploaded once at engine construction).
    Weight(&'a str),
}

struct LoadedProgram {
    meta: ProgramMeta,
    exe: xla::PjRtLoadedExecutable,
}

/// Counters the perf pass and metrics layer read.
#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    pub calls: u64,
    pub host_bytes_in: u64,
    pub host_bytes_out: u64,
    pub exec_seconds: f64,
    /// Per-program (calls, exec seconds) — the L2/L3 profiling signal.
    pub per_program: std::collections::BTreeMap<String, (u64, f64)>,
}

pub struct Engine {
    manifest: Manifest,
    client: xla::PjRtClient,
    programs: BTreeMap<String, LoadedProgram>,
    weights: BTreeMap<String, xla::PjRtBuffer>,
    /// host copies kept for weight-free reconstruction in tests/tools
    weight_shapes: BTreeMap<String, Vec<usize>>,
    stats: std::cell::RefCell<EngineStats>,
}

impl Engine {
    /// Compile all programs of a manifest and upload its weights.
    pub fn load(manifest: Manifest) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut programs = BTreeMap::new();
        for (name, meta) in &manifest.programs {
            let path = manifest.dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling program '{name}'"))?;
            programs.insert(name.clone(), LoadedProgram { meta: meta.clone(), exe });
        }

        let mut weights = BTreeMap::new();
        let mut weight_shapes = BTreeMap::new();
        for w in &manifest.weights {
            let host = HostTensor::read_bin(&manifest.dir.join(&w.file), w.shape.clone(), DType::F32)
                .with_context(|| format!("loading weight '{}'", w.name))?;
            let dims: Vec<usize> = host.shape().to_vec();
            let buf = client
                .buffer_from_host_buffer(host.as_f32()?, &dims, None)
                .with_context(|| format!("uploading weight '{}'", w.name))?;
            weights.insert(w.name.clone(), buf);
            weight_shapes.insert(w.name.clone(), w.shape.clone());
        }

        Ok(Engine {
            manifest,
            client,
            programs,
            weights,
            weight_shapes,
            stats: std::cell::RefCell::new(EngineStats::default()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn stats(&self) -> EngineStats {
        self.stats.borrow().clone()
    }

    pub fn has_weight(&self, name: &str) -> bool {
        self.weights.contains_key(name)
    }

    /// Execute `program` with ordered `args` (must match the manifest
    /// signature). Returns the decomposed output tensors.
    pub fn execute(&self, program: &str, args: &[Arg]) -> Result<Vec<HostTensor>> {
        let lp = self
            .programs
            .get(program)
            .with_context(|| format!("program '{program}' not loaded"))?;
        if args.len() != lp.meta.params.len() {
            bail!(
                "program '{program}': {} args given, signature wants {}",
                args.len(),
                lp.meta.params.len()
            );
        }

        // Bind: temp buffers for host args, references for weights.
        let mut temps: Vec<xla::PjRtBuffer> = Vec::new();
        let mut temp_idx: Vec<Option<usize>> = Vec::with_capacity(args.len());
        let mut in_bytes = 0u64;
        for (arg, param) in args.iter().zip(&lp.meta.params) {
            match arg {
                Arg::Host(t) => {
                    if t.shape() != param.shape.as_slice() {
                        bail!(
                            "program '{program}' param '{}': shape {:?} != declared {:?}",
                            param.name,
                            t.shape(),
                            param.shape
                        );
                    }
                    if t.dtype() != param.dtype {
                        bail!(
                            "program '{program}' param '{}': dtype mismatch",
                            param.name
                        );
                    }
                    let dims: Vec<usize> = t.shape().to_vec();
                    let buf = match t {
                        HostTensor::F32 { data, .. } => {
                            self.client.buffer_from_host_buffer(data, &dims, None)?
                        }
                        HostTensor::I32 { data, .. } => {
                            self.client.buffer_from_host_buffer(data, &dims, None)?
                        }
                    };
                    in_bytes += (t.len() * 4) as u64;
                    temps.push(buf);
                    temp_idx.push(Some(temps.len() - 1));
                }
                Arg::Weight(name) => {
                    if !self.weights.contains_key(*name) {
                        bail!("program '{program}': unknown weight '{name}'");
                    }
                    // shape check against signature
                    let ws = &self.weight_shapes[*name];
                    if ws != &param.shape {
                        bail!(
                            "program '{program}' param '{}': weight '{name}' shape {ws:?} != declared {:?}",
                            param.name,
                            param.shape
                        );
                    }
                    temp_idx.push(None);
                }
            }
        }
        let bound: Vec<&xla::PjRtBuffer> = args
            .iter()
            .zip(&temp_idx)
            .map(|(arg, ti)| match (arg, ti) {
                (Arg::Host(_), Some(i)) => &temps[*i],
                (Arg::Weight(name), None) => &self.weights[*name],
                _ => unreachable!(),
            })
            .collect();

        let t0 = std::time::Instant::now();
        let result = lp
            .exe
            .execute_b(&bound)
            .with_context(|| format!("executing '{program}'"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("copying result tuple to host")?;
        let elapsed = t0.elapsed().as_secs_f64();

        let leaves = tuple.to_tuple().context("decomposing result tuple")?;
        if leaves.len() != lp.meta.outputs.len() {
            bail!(
                "program '{program}': {} outputs, manifest declares {}",
                leaves.len(),
                lp.meta.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(leaves.len());
        let mut out_bytes = 0u64;
        for (lit, meta) in leaves.iter().zip(&lp.meta.outputs) {
            let t = HostTensor::from_literal(lit, meta.shape.clone(), meta.dtype)?;
            out_bytes += (t.len() * 4) as u64;
            out.push(t);
        }

        let mut st = self.stats.borrow_mut();
        st.calls += 1;
        st.host_bytes_in += in_bytes;
        st.host_bytes_out += out_bytes;
        st.exec_seconds += elapsed;
        let entry = st.per_program.entry(program.to_string()).or_insert((0, 0.0));
        entry.0 += 1;
        entry.1 += elapsed;
        Ok(out)
    }
}
