//! PJRT runtime: load HLO-text artifacts produced by `python/compile/aot.py`
//! and execute them on the CPU PJRT client from the decode hot path.
//!
//! * [`manifest`] — typed `manifest.json` (the python↔rust contract).
//! * [`tensor`]   — host tensors + `.bin` weight IO + literal conversion.
//! * [`engine`]   — compile-once / execute-many with persistent device
//!   weights.

pub mod engine;
pub mod manifest;
pub mod tensor;

pub use engine::{Arg, Engine, EngineStats};
pub use manifest::{artifacts_root, Manifest, ModelDims};
pub use tensor::{DType, HostTensor};
