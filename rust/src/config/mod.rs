//! Configuration system: JSON config files + CLI overrides + named presets.
//!
//! A `ServeConfig` fully determines a serving deployment: which artifact
//! preset to load, the selection policy, batching/speculation geometry, the
//! hardware cost profile and (optionally) the expert-parallel topology.
//! Everything is overridable from the launcher CLI (`xshare serve --policy
//! batch:24:1 --batch 16 …`) and loadable from a JSON file (`--config
//! deploy.json`).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::coordinator::admission::AdmissionKind;
use crate::ep::PlacementKind;
use crate::selection::PolicyKind;
use crate::util::cli::Args;
use crate::util::json::Json;

/// Expert-parallel topology.
#[derive(Debug, Clone, PartialEq)]
pub struct EpConfig {
    pub n_gpus: usize,
    pub placement: PlacementKind,
}

/// Where speculative draft tokens come from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpecDraft {
    /// The compiled dense draft model (default; requires the preset to
    /// ship `draft_step`).
    Model,
    /// N-gram lookup over each row's own prompt + generated history
    /// (prompt-lookup decoding) — drafts cost no model forward at all.
    Lookup,
}

impl SpecDraft {
    pub fn parse(s: &str) -> Result<SpecDraft, String> {
        match s {
            "model" => Ok(SpecDraft::Model),
            "lookup" | "ngram" => Ok(SpecDraft::Lookup),
            other => Err(format!("unknown spec draft source '{other}' (model | lookup)")),
        }
    }
}

impl std::fmt::Display for SpecDraft {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SpecDraft::Model => write!(f, "model"),
            SpecDraft::Lookup => write!(f, "lookup"),
        }
    }
}

/// A full serving deployment description.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Artifact preset directory name under `artifacts/`.
    pub preset: String,
    /// Expert selection policy (the paper's algorithms or a baseline).
    pub policy: PolicyKind,
    /// Target decode batch size (requests per step, ≤ manifest max_batch).
    pub batch_size: usize,
    /// Speculative length L_s (0 = speculation off). With per-row phase
    /// machines this is the MAXIMUM per-row draft depth, not a batch-wide
    /// constant.
    pub spec_len: usize,
    /// Adapt each row's draft depth within `[0, spec_len]` from a
    /// per-traffic-class acceptance EMA, and weight speculative positions
    /// by the class's acceptance prior during selection. Off by default
    /// (uniform depth — the legacy behaviour).
    pub spec_adaptive: bool,
    /// Charge-aware depth (`--spec-charge-aware`): replace the adaptive
    /// controller's fixed usefulness threshold with ledger-priced
    /// economics — draft one position deeper while its acceptance-weighted
    /// expected commit value beats `cost::Ledger::marginal_spec_cost`
    /// under the last charged batch geometry. Depth choice is
    /// scheduling-only (byte-identical outputs). Requires
    /// `--spec-adaptive`. Off by default.
    pub spec_charge_aware: bool,
    /// Draft source for speculation: the dense draft model or n-gram
    /// lookup over each row's own history.
    pub spec_draft: SpecDraft,
    /// Prompt tokens a prefilling row advances per serving step. 1 = the
    /// legacy one-token-per-step walk; >1 uses the chunked-prefill artifact
    /// (requires the preset to ship `prefill_attn_router`). Bounded by the
    /// compiled `max_seq` at `ServeLoop` construction.
    pub prefill_chunk: usize,
    /// Chunk-batched expert selection (`--chunk-shared-selection`): within
    /// a prefill wave, pool the per-position router scores and run the
    /// paper's modular greedy objective once, so every position of a chunk
    /// shares one expert set per layer (cheaper fused forwards). **Lossy**:
    /// routing may differ from exact per-position top-k, so the serving
    /// harness measures the distortion through `coordinator::fidelity` and
    /// reports it as a first-class metric (`shared_selection_fidelity`) —
    /// never silently. Requires chunked prefill (`prefill_chunk` ≥ 2). Off
    /// by default (exact routing, byte-identical outputs).
    pub chunk_shared_selection: bool,
    /// Hardware cost profile for OTPS accounting.
    pub hardware: String,
    /// Admission policy: which queued request takes the next free batch
    /// slot (fifo | priority | edf | footprint).
    pub admission: AdmissionKind,
    /// Admission-queue depth bound; submits beyond it are rejected with a
    /// typed `QueueFull` error. 0 = unbounded (legacy-compatible default).
    pub max_queue: usize,
    /// EMA decay for footprint tracking (admission co-scheduling,
    /// eviction, rebalancing). Valid on the closed interval `[0, 1]`:
    /// `0.0` = no memory (latest observation wins), `1.0` = freeze at the
    /// first observation. Default 0.9 (~10-step memory).
    pub footprint_decay: f32,
    /// Footprint-aware slot eviction (`--ep-evict`): when the queue holds
    /// a request whose predicted expert set fits the running batch far
    /// better than the worst-fitting running row does, preempt that row
    /// back to the queue (bounded per request; resumed losslessly from its
    /// committed history — see `coordinator::eviction`). Requires
    /// footprint admission. Off by default.
    pub ep_evict: bool,
    /// Dynamic placement (`--ep-rebalance N`): every N slot frees, greedily
    /// reassign experts to GPUs to minimize expected MaxLoad under the
    /// tracked class mix (adopted only when it strictly improves). 0 = off
    /// (static placement, the default). Requires an EP topology and
    /// footprint admission.
    pub ep_rebalance: usize,
    /// Replica residency slack (`--ep-replica-slack F`): each GPU may hold
    /// up to ⌈F·N/G⌉ expert copies, so F−1 is the fractional weight-memory
    /// overhead replication may spend. 1.0 (default) leaves no headroom
    /// beyond the balanced partition; values > 1 require an EP topology.
    pub ep_replica_slack: f64,
    /// Incremental migration (`--ep-migrate-budget B`): placement changes
    /// on the rebalance clock become bounded plans of ≤ B expert
    /// copies/drops per step, charged through the interconnect and adopted
    /// only when the expected straggler saving beats the transfer cost.
    /// 0 = off (the legacy free instantaneous swap). Requires
    /// `--ep-rebalance` (migration rides the same clock and weights).
    pub ep_migrate_budget: usize,
    /// Footprint-driven replica prefetch (`--ep-prefetch`): each step, run
    /// the migration planner over the QUEUED classes' predicted expert
    /// sets so replicas are resident (and paid for) before that traffic
    /// admits. Requires `--ep-migrate-budget` > 0. Off by default.
    pub ep_prefetch: bool,
    /// Shared-prefix KV cache budget in MiB (`--prefix-cache-mb`):
    /// releasing rows offer their committed-prefix KV to a VRAM-budgeted
    /// LRU cache; admissions whose prompt extends a cached entry restore
    /// the slab and prefill only the suffix (see
    /// `coordinator::prefix_cache`). 0 = off (the default).
    pub prefix_cache_mb: usize,
    /// Minimum prefix length worth caching (`--prefix-min-tokens`): slabs
    /// shorter than this are not offered — a tiny restore saves less than
    /// its bookkeeping. Must be ≥ 1; only consulted when the cache is on.
    pub prefix_min_tokens: usize,
    /// Fleet tier (`--fleet-replicas N`): run N independent serve-loop
    /// replicas, each on its own thread, behind the footprint-affine
    /// router (`fleet::Fleet`). 1 (default) = the single-loop path,
    /// byte-unchanged.
    pub fleet_replicas: usize,
    /// Fleet routing mode (`--fleet-affinity class|round-robin`): `class`
    /// (default) sends each request to the rendezvous-preferred replica of
    /// its traffic class so in-batch expert sharing compounds per replica;
    /// `round-robin` is the class-blind baseline the fleet bench compares
    /// against.
    pub fleet_affinity: crate::fleet::AffinityMode,
    /// Queue-depth high-water mark (`--fleet-high-water Q`): an affine
    /// target whose admission queue has reached Q is Busy, and the submit
    /// spills to the least-loaded healthy replica instead. 0 (default) =
    /// no backpressure spilling (pure affinity). Needs ≥ 2 replicas.
    pub fleet_high_water: usize,
    /// Health-probe clock (`--fleet-probe-every N`): every N fleet submits
    /// the router re-probes every live replica's queue depth and refreshes
    /// its Healthy/Busy state (Dead is terminal). Must be ≥ 1.
    pub fleet_probe_every: usize,
    /// Expert-parallel topology (None = single GPU).
    pub ep: Option<EpConfig>,
    /// Server bind address.
    pub addr: String,
    /// Global seed (sampling, workload).
    pub seed: u64,
    /// Max new tokens per request default.
    pub max_new_tokens: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            preset: "gptoss-mini".into(),
            policy: PolicyKind::Vanilla,
            batch_size: 16,
            spec_len: 0,
            spec_adaptive: false,
            spec_charge_aware: false,
            spec_draft: SpecDraft::Model,
            prefill_chunk: 1,
            chunk_shared_selection: false,
            hardware: "h100".into(),
            admission: AdmissionKind::Fifo,
            max_queue: 0,
            footprint_decay: 0.9,
            ep_evict: false,
            ep_rebalance: 0,
            ep_replica_slack: 1.0,
            ep_migrate_budget: 0,
            ep_prefetch: false,
            prefix_cache_mb: 0,
            prefix_min_tokens: 8,
            fleet_replicas: 1,
            fleet_affinity: crate::fleet::AffinityMode::Class,
            fleet_high_water: 0,
            fleet_probe_every: 16,
            ep: None,
            addr: "127.0.0.1:7431".into(),
            seed: 0,
            max_new_tokens: 32,
        }
    }
}

impl ServeConfig {
    /// Load from a JSON file. Unknown keys are rejected (typos should fail
    /// loudly, not silently deploy a default).
    pub fn from_json_file(path: &Path) -> Result<ServeConfig> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
        let root = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;
        let obj = root.as_obj().context("config root must be an object")?;

        let known = [
            "preset", "policy", "batch_size", "spec_len", "spec_adaptive",
            "spec_charge_aware", "spec_draft",
            "prefill_chunk", "chunk_shared_selection", "hardware", "admission",
            "max_queue", "footprint_decay",
            "ep_evict", "ep_rebalance", "ep_replica_slack", "ep_migrate_budget",
            "ep_prefetch", "prefix_cache_mb", "prefix_min_tokens", "fleet_replicas",
            "fleet_affinity", "fleet_high_water", "fleet_probe_every", "ep", "addr",
            "seed", "max_new_tokens",
        ];
        for key in obj.keys() {
            if !known.contains(&key.as_str()) {
                bail!("unknown config key '{key}' (known: {known:?})");
            }
        }

        let mut cfg = ServeConfig::default();
        if let Some(v) = root.get("preset") {
            cfg.preset = v.as_str().context("preset")?.to_string();
        }
        if let Some(v) = root.get("policy") {
            cfg.policy = PolicyKind::parse(v.as_str().context("policy")?)
                .map_err(anyhow::Error::msg)?;
        }
        if let Some(v) = root.get("batch_size") {
            cfg.batch_size = v.as_usize().context("batch_size")?;
        }
        if let Some(v) = root.get("spec_len") {
            cfg.spec_len = v.as_usize().context("spec_len")?;
        }
        if let Some(v) = root.get("spec_adaptive") {
            cfg.spec_adaptive = v.as_bool().context("spec_adaptive")?;
        }
        if let Some(v) = root.get("spec_charge_aware") {
            cfg.spec_charge_aware = v.as_bool().context("spec_charge_aware")?;
        }
        if let Some(v) = root.get("spec_draft") {
            cfg.spec_draft = SpecDraft::parse(v.as_str().context("spec_draft")?)
                .map_err(anyhow::Error::msg)?;
        }
        if let Some(v) = root.get("prefill_chunk") {
            cfg.prefill_chunk = v.as_usize().context("prefill_chunk")?;
        }
        if let Some(v) = root.get("chunk_shared_selection") {
            cfg.chunk_shared_selection = v.as_bool().context("chunk_shared_selection")?;
        }
        if let Some(v) = root.get("hardware") {
            cfg.hardware = v.as_str().context("hardware")?.to_string();
        }
        if let Some(v) = root.get("admission") {
            cfg.admission = AdmissionKind::parse(v.as_str().context("admission")?)
                .map_err(anyhow::Error::msg)?;
        }
        if let Some(v) = root.get("max_queue") {
            cfg.max_queue = v.as_usize().context("max_queue")?;
        }
        if let Some(v) = root.get("footprint_decay") {
            cfg.footprint_decay = v.as_f64().context("footprint_decay")? as f32;
        }
        if let Some(v) = root.get("ep_evict") {
            cfg.ep_evict = v.as_bool().context("ep_evict")?;
        }
        if let Some(v) = root.get("ep_rebalance") {
            cfg.ep_rebalance = v.as_usize().context("ep_rebalance")?;
        }
        if let Some(v) = root.get("ep_replica_slack") {
            cfg.ep_replica_slack = v.as_f64().context("ep_replica_slack")?;
        }
        if let Some(v) = root.get("ep_migrate_budget") {
            cfg.ep_migrate_budget = v.as_usize().context("ep_migrate_budget")?;
        }
        if let Some(v) = root.get("ep_prefetch") {
            cfg.ep_prefetch = v.as_bool().context("ep_prefetch")?;
        }
        if let Some(v) = root.get("prefix_cache_mb") {
            cfg.prefix_cache_mb = v.as_usize().context("prefix_cache_mb")?;
        }
        if let Some(v) = root.get("prefix_min_tokens") {
            cfg.prefix_min_tokens = v.as_usize().context("prefix_min_tokens")?;
        }
        if let Some(v) = root.get("fleet_replicas") {
            cfg.fleet_replicas = v.as_usize().context("fleet_replicas")?;
        }
        if let Some(v) = root.get("fleet_affinity") {
            cfg.fleet_affinity =
                crate::fleet::AffinityMode::parse(v.as_str().context("fleet_affinity")?)
                    .map_err(anyhow::Error::msg)?;
        }
        if let Some(v) = root.get("fleet_high_water") {
            cfg.fleet_high_water = v.as_usize().context("fleet_high_water")?;
        }
        if let Some(v) = root.get("fleet_probe_every") {
            cfg.fleet_probe_every = v.as_usize().context("fleet_probe_every")?;
        }
        if let Some(v) = root.get("addr") {
            cfg.addr = v.as_str().context("addr")?.to_string();
        }
        if let Some(v) = root.get("seed") {
            cfg.seed = v.as_i64().context("seed")? as u64;
        }
        if let Some(v) = root.get("max_new_tokens") {
            cfg.max_new_tokens = v.as_usize().context("max_new_tokens")?;
        }
        if let Some(v) = root.get("ep") {
            if *v != Json::Null {
                cfg.ep = Some(EpConfig {
                    n_gpus: v.req("n_gpus")?.as_usize().context("ep.n_gpus")?,
                    placement: parse_placement(
                        v.get("placement").and_then(|p| p.as_str()).unwrap_or("contiguous"),
                    )?,
                });
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Apply CLI overrides on top of this config.
    pub fn apply_args(mut self, args: &Args) -> Result<ServeConfig> {
        if let Some(v) = args.get("preset") {
            self.preset = v.to_string();
        }
        if let Some(v) = args.get("policy") {
            self.policy = PolicyKind::parse(v).map_err(anyhow::Error::msg)?;
        }
        if args.has("batch") {
            self.batch_size = args.usize_or("batch", self.batch_size);
        }
        if args.has("spec-len") {
            self.spec_len = args.usize_or("spec-len", self.spec_len);
        }
        if args.bool("spec-adaptive") {
            self.spec_adaptive = true;
        }
        if args.bool("spec-charge-aware") {
            self.spec_charge_aware = true;
        }
        if let Some(v) = args.get("spec-draft") {
            self.spec_draft = SpecDraft::parse(v).map_err(anyhow::Error::msg)?;
        }
        if args.has("prefill-chunk") {
            self.prefill_chunk = args.usize_or("prefill-chunk", self.prefill_chunk);
        }
        if args.bool("chunk-shared-selection") {
            self.chunk_shared_selection = true;
        }
        if let Some(v) = args.get("hardware") {
            self.hardware = v.to_string();
        }
        if let Some(v) = args.get("admission") {
            self.admission = AdmissionKind::parse(v).map_err(anyhow::Error::msg)?;
        }
        if args.has("max-queue") {
            self.max_queue = args.usize_or("max-queue", self.max_queue);
        }
        if args.has("footprint-decay") {
            self.footprint_decay =
                args.f64_or("footprint-decay", self.footprint_decay as f64) as f32;
        }
        if args.bool("ep-evict") {
            self.ep_evict = true;
        }
        if args.has("ep-rebalance") {
            self.ep_rebalance = args.usize_or("ep-rebalance", self.ep_rebalance);
        }
        if args.has("ep-replica-slack") {
            self.ep_replica_slack =
                args.f64_or("ep-replica-slack", self.ep_replica_slack);
        }
        if args.has("ep-migrate-budget") {
            self.ep_migrate_budget =
                args.usize_or("ep-migrate-budget", self.ep_migrate_budget);
        }
        if args.bool("ep-prefetch") {
            self.ep_prefetch = true;
        }
        if args.has("prefix-cache-mb") {
            self.prefix_cache_mb = args.usize_or("prefix-cache-mb", self.prefix_cache_mb);
        }
        if args.has("prefix-min-tokens") {
            self.prefix_min_tokens =
                args.usize_or("prefix-min-tokens", self.prefix_min_tokens);
        }
        if args.has("fleet-replicas") {
            self.fleet_replicas = args.usize_or("fleet-replicas", self.fleet_replicas);
        }
        if let Some(v) = args.get("fleet-affinity") {
            self.fleet_affinity =
                crate::fleet::AffinityMode::parse(v).map_err(anyhow::Error::msg)?;
        }
        if args.has("fleet-high-water") {
            self.fleet_high_water =
                args.usize_or("fleet-high-water", self.fleet_high_water);
        }
        if args.has("fleet-probe-every") {
            self.fleet_probe_every =
                args.usize_or("fleet-probe-every", self.fleet_probe_every);
        }
        if let Some(v) = args.get("addr") {
            self.addr = v.to_string();
        }
        if args.has("seed") {
            self.seed = args.usize_or("seed", self.seed as usize) as u64;
        }
        if args.has("max-new-tokens") {
            self.max_new_tokens = args.usize_or("max-new-tokens", self.max_new_tokens);
        }
        if args.has("ep-gpus") {
            self.ep = Some(EpConfig {
                n_gpus: args.usize_or("ep-gpus", 8),
                placement: parse_placement(&args.str_or("ep-placement", "contiguous"))?,
            });
        }
        self.validate()?;
        Ok(self)
    }

    pub fn validate(&self) -> Result<()> {
        if self.batch_size == 0 {
            bail!("batch_size must be ≥ 1");
        }
        if self.batch_size * (1 + self.spec_len) > 1024 {
            bail!("effective batch {} too large", self.batch_size * (1 + self.spec_len));
        }
        if self.spec_adaptive && self.spec_len == 0 {
            bail!("--spec-adaptive needs speculation on (spec_len ≥ 1)");
        }
        if self.spec_charge_aware && !self.spec_adaptive {
            bail!(
                "--spec-charge-aware needs --spec-adaptive: charge-aware depth \
                 replaces the adaptive controller's usefulness threshold, so there \
                 is no controller to price without it"
            );
        }
        if self.prefill_chunk == 0 {
            bail!("prefill_chunk must be ≥ 1 (1 = one-token-per-step prefill)");
        }
        if self.prefill_chunk > 4096 {
            // compiled max_seq is checked against the manifest at ServeLoop
            // construction; this is the config-level sanity ceiling
            bail!("prefill_chunk {} is beyond any compiled sequence length", self.prefill_chunk);
        }
        if self.chunk_shared_selection && self.prefill_chunk <= 1 {
            bail!(
                "--chunk-shared-selection needs chunked prefill (--prefill-chunk T ≥ 2): \
                 sharing one expert set across a chunk's positions is meaningless when \
                 every chunk is a single token"
            );
        }
        if !(0.0..=1.0).contains(&self.footprint_decay) || !self.footprint_decay.is_finite()
        {
            bail!(
                "footprint_decay {} outside [0, 1] (0 = no memory, 1 = freeze at the \
                 first observation; both endpoints are legal)",
                self.footprint_decay
            );
        }
        if self.ep_evict && self.admission != AdmissionKind::FootprintAware {
            bail!(
                "--ep-evict needs footprint admission (--admission footprint): eviction \
                 scores rows and queue candidates by tracked expert footprints"
            );
        }
        if self.ep_rebalance > 0 {
            if self.ep.is_none() {
                bail!("--ep-rebalance needs an EP topology (--ep-gpus N)");
            }
            if self.admission != AdmissionKind::FootprintAware {
                bail!(
                    "--ep-rebalance needs footprint admission (--admission footprint): \
                     rebalancing weights experts by the tracked class mix"
                );
            }
        }
        if !self.ep_replica_slack.is_finite() || self.ep_replica_slack < 1.0 {
            bail!(
                "ep_replica_slack {} must be a finite value ≥ 1.0 (1.0 = no replica \
                 headroom beyond the balanced partition)",
                self.ep_replica_slack
            );
        }
        if self.ep_replica_slack > 1.0 && self.ep.is_none() {
            bail!("--ep-replica-slack > 1 needs an EP topology (--ep-gpus N)");
        }
        if self.ep_migrate_budget > 0 && self.ep_rebalance == 0 {
            bail!(
                "--ep-migrate-budget needs --ep-rebalance N: incremental migration \
                 rides the rebalance clock and its tracked class-mix weights"
            );
        }
        if self.ep_prefetch && self.ep_migrate_budget == 0 {
            bail!(
                "--ep-prefetch needs --ep-migrate-budget B: prefetch schedules \
                 bounded replica migrations for the predicted queued mix"
            );
        }
        if self.fleet_replicas == 0 {
            bail!("fleet_replicas must be ≥ 1 (1 = the single-loop path)");
        }
        if self.fleet_high_water > 0 && self.fleet_replicas < 2 {
            bail!(
                "--fleet-high-water needs --fleet-replicas ≥ 2: backpressure \
                 spilling has nowhere to spill with a single replica"
            );
        }
        if self.fleet_probe_every == 0 {
            bail!("fleet_probe_every must be ≥ 1 (probe every N fleet submits)");
        }
        if self.prefix_min_tokens == 0 {
            bail!(
                "prefix_min_tokens must be ≥ 1: a zero-length prefix has no KV to \
                 restore, and every cached entry must leave a prompt suffix to feed"
            );
        }
        if let Some(ep) = &self.ep {
            if ep.n_gpus == 0 {
                bail!("ep.n_gpus must be ≥ 1");
            }
        }
        if matches!(self.policy, PolicyKind::GpuAware { .. }) && self.ep.is_none() {
            bail!("gpu-aware policy requires an EP topology (--ep-gpus N)");
        }
        Ok(())
    }

    /// Effective tokens per verify step: B × (1 + L_s).
    pub fn effective_batch(&self) -> usize {
        self.batch_size * (1 + self.spec_len)
    }
}

pub fn parse_placement(s: &str) -> Result<PlacementKind> {
    match s {
        "contiguous" => Ok(PlacementKind::Contiguous),
        "round_robin" | "round-robin" => Ok(PlacementKind::RoundRobin),
        other => {
            if let Some(seed) = other.strip_prefix("random:") {
                Ok(PlacementKind::Random(seed.parse().context("random:<seed>")?))
            } else {
                bail!("unknown placement '{other}' (contiguous | round_robin | random:<seed>)")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_tmp(name: &str, text: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("xshare_config_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(name);
        std::fs::write(&p, text).unwrap();
        p
    }

    #[test]
    fn default_is_valid() {
        ServeConfig::default().validate().unwrap();
    }

    #[test]
    fn json_file_roundtrip() {
        let p = write_tmp(
            "a.json",
            r#"{"preset":"dsr1-mini","policy":"gpu:1:5","batch_size":8,
               "ep":{"n_gpus":8,"placement":"round_robin"},"seed":7}"#,
        );
        let cfg = ServeConfig::from_json_file(&p).unwrap();
        assert_eq!(cfg.preset, "dsr1-mini");
        assert_eq!(cfg.policy, PolicyKind::GpuAware { k0: 1, per_gpu_budget: 5 });
        assert_eq!(cfg.ep.as_ref().unwrap().n_gpus, 8);
        assert_eq!(cfg.seed, 7);
    }

    #[test]
    fn unknown_key_rejected() {
        let p = write_tmp("b.json", r#"{"presett":"oops"}"#);
        let err = ServeConfig::from_json_file(&p).unwrap_err();
        assert!(format!("{err:#}").contains("presett"));
    }

    #[test]
    fn gpu_policy_without_ep_rejected() {
        let p = write_tmp("c.json", r#"{"policy":"gpu:1:5"}"#);
        assert!(ServeConfig::from_json_file(&p).is_err());
    }

    #[test]
    fn cli_overrides() {
        let args = Args::parse(
            "--policy spec:1:0:4 --batch 4 --spec-len 3 --seed 9"
                .split_whitespace()
                .map(String::from),
        );
        let cfg = ServeConfig::default().apply_args(&args).unwrap();
        assert_eq!(
            cfg.policy,
            PolicyKind::SpecAware { k0: 1, batch_budget: 0, req_budget: 4 }
        );
        assert_eq!(cfg.effective_batch(), 16);
        assert_eq!(cfg.seed, 9);
    }

    #[test]
    fn prefill_chunk_json_roundtrip_and_validation() {
        let p = write_tmp("d.json", r#"{"prefill_chunk":8,"batch_size":4}"#);
        let cfg = ServeConfig::from_json_file(&p).unwrap();
        assert_eq!(cfg.prefill_chunk, 8);

        // default stays the legacy one-token walk
        assert_eq!(ServeConfig::default().prefill_chunk, 1);

        // zero rejected: a chunk must advance at least one token
        let z = write_tmp("e.json", r#"{"prefill_chunk":0}"#);
        let err = ServeConfig::from_json_file(&z).unwrap_err();
        assert!(format!("{err:#}").contains("prefill_chunk"));

        // absurd chunk rejected at the config level (manifest max_seq is
        // enforced again at ServeLoop construction)
        let big = ServeConfig { prefill_chunk: 5000, ..ServeConfig::default() };
        assert!(big.validate().is_err());
    }

    #[test]
    fn prefill_chunk_cli_override() {
        let args = Args::parse(
            "--prefill-chunk 16 --batch 4".split_whitespace().map(String::from),
        );
        let cfg = ServeConfig::default().apply_args(&args).unwrap();
        assert_eq!(cfg.prefill_chunk, 16);
        let bad = Args::parse("--prefill-chunk 0".split_whitespace().map(String::from));
        assert!(ServeConfig::default().apply_args(&bad).is_err());
    }

    #[test]
    fn spec_adaptive_and_draft_roundtrip_and_validation() {
        // defaults: uniform depth, model draft — the legacy behaviour
        let d = ServeConfig::default();
        assert!(!d.spec_adaptive);
        assert_eq!(d.spec_draft, SpecDraft::Model);

        let p = write_tmp(
            "spec.json",
            r#"{"spec_len":3,"spec_adaptive":true,"spec_draft":"lookup"}"#,
        );
        let cfg = ServeConfig::from_json_file(&p).unwrap();
        assert!(cfg.spec_adaptive);
        assert_eq!(cfg.spec_draft, SpecDraft::Lookup);

        // adaptive depth without speculation is a config error
        let bad = write_tmp("spec_bad.json", r#"{"spec_adaptive":true}"#);
        let err = ServeConfig::from_json_file(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("spec-adaptive"));

        // unknown draft source fails loudly
        let bad = write_tmp("spec_bad2.json", r#"{"spec_draft":"oracle"}"#);
        assert!(ServeConfig::from_json_file(&bad).is_err());

        let args = Args::parse(
            "--spec-len 2 --spec-adaptive --spec-draft ngram"
                .split_whitespace()
                .map(String::from),
        );
        let cfg = ServeConfig::default().apply_args(&args).unwrap();
        assert!(cfg.spec_adaptive);
        assert_eq!(cfg.spec_draft, SpecDraft::Lookup);
        assert_eq!(SpecDraft::Lookup.to_string(), "lookup");
        let bad =
            Args::parse("--spec-adaptive".split_whitespace().map(String::from));
        assert!(ServeConfig::default().apply_args(&bad).is_err());
    }

    #[test]
    fn spec_charge_aware_roundtrip_and_validation() {
        // default off — the fixed usefulness threshold stays the baseline
        assert!(!ServeConfig::default().spec_charge_aware);

        let p = write_tmp(
            "spec_charge.json",
            r#"{"spec_len":3,"spec_adaptive":true,"spec_charge_aware":true}"#,
        );
        let cfg = ServeConfig::from_json_file(&p).unwrap();
        assert!(cfg.spec_charge_aware);

        // charge-aware without the adaptive controller is a config error
        let bad = write_tmp(
            "spec_charge_bad.json",
            r#"{"spec_len":3,"spec_charge_aware":true}"#,
        );
        let err = ServeConfig::from_json_file(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("spec-charge-aware"));

        let args = Args::parse(
            "--spec-len 2 --spec-adaptive --spec-charge-aware"
                .split_whitespace()
                .map(String::from),
        );
        let cfg = ServeConfig::default().apply_args(&args).unwrap();
        assert!(cfg.spec_charge_aware);
        let bad = Args::parse(
            "--spec-len 2 --spec-charge-aware".split_whitespace().map(String::from),
        );
        assert!(ServeConfig::default().apply_args(&bad).is_err());
    }

    #[test]
    fn admission_json_and_cli_roundtrip() {
        // default: fifo + unbounded queue (byte-identical to the legacy
        // hard-coded admission)
        let d = ServeConfig::default();
        assert_eq!(d.admission, AdmissionKind::Fifo);
        assert_eq!(d.max_queue, 0);

        let p = write_tmp("adm.json", r#"{"admission":"footprint","max_queue":64}"#);
        let cfg = ServeConfig::from_json_file(&p).unwrap();
        assert_eq!(cfg.admission, AdmissionKind::FootprintAware);
        assert_eq!(cfg.max_queue, 64);

        let bad = write_tmp("adm_bad.json", r#"{"admission":"lifo"}"#);
        assert!(ServeConfig::from_json_file(&bad).is_err());

        let args = Args::parse(
            "--admission edf --max-queue 8".split_whitespace().map(String::from),
        );
        let cfg = ServeConfig::default().apply_args(&args).unwrap();
        assert_eq!(cfg.admission, AdmissionKind::SloEdf);
        assert_eq!(cfg.max_queue, 8);
        let bad = Args::parse("--admission random".split_whitespace().map(String::from));
        assert!(ServeConfig::default().apply_args(&bad).is_err());
    }

    #[test]
    fn ep_serving_knobs_roundtrip_and_validation() {
        // defaults: static placement, no eviction, 0.9 decay
        let d = ServeConfig::default();
        assert!(!d.ep_evict);
        assert_eq!(d.ep_rebalance, 0);
        assert!((d.footprint_decay - 0.9).abs() < 1e-6);

        let p = write_tmp(
            "ep_serve.json",
            r#"{"admission":"footprint","footprint_decay":0.8,"ep_evict":true,
               "ep_rebalance":4,"ep":{"n_gpus":4}}"#,
        );
        let cfg = ServeConfig::from_json_file(&p).unwrap();
        assert!(cfg.ep_evict);
        assert_eq!(cfg.ep_rebalance, 4);
        assert!((cfg.footprint_decay - 0.8).abs() < 1e-6);

        // both decay endpoints are LEGAL (0 = no memory, 1 = freeze) —
        // the old Footprint::observe guard rejected exactly one of them
        for decay in [0.0f32, 1.0] {
            let cfg = ServeConfig { footprint_decay: decay, ..ServeConfig::default() };
            cfg.validate().unwrap();
        }
        // …but out-of-range fails loudly at parse time, not deep in serving
        for decay in [-0.1f32, 1.1, f32::NAN] {
            let cfg = ServeConfig { footprint_decay: decay, ..ServeConfig::default() };
            let err = cfg.validate().unwrap_err();
            assert!(format!("{err:#}").contains("footprint_decay"), "{err:#}");
        }

        // eviction without footprint admission is a config error
        let bad = write_tmp("ep_evict_bad.json", r#"{"ep_evict":true}"#);
        let err = ServeConfig::from_json_file(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("footprint admission"));

        // rebalance needs both an EP topology and footprint admission
        let bad = write_tmp(
            "ep_reb_bad.json",
            r#"{"admission":"footprint","ep_rebalance":2}"#,
        );
        assert!(ServeConfig::from_json_file(&bad).is_err());
        let bad =
            write_tmp("ep_reb_bad2.json", r#"{"ep_rebalance":2,"ep":{"n_gpus":2}}"#);
        assert!(ServeConfig::from_json_file(&bad).is_err());

        // CLI spellings
        let args = Args::parse(
            "--admission footprint --ep-gpus 4 --ep-evict --ep-rebalance 8 \
             --footprint-decay 0.95"
                .split_whitespace()
                .map(String::from),
        );
        let cfg = ServeConfig::default().apply_args(&args).unwrap();
        assert!(cfg.ep_evict);
        assert_eq!(cfg.ep_rebalance, 8);
        assert!((cfg.footprint_decay - 0.95).abs() < 1e-6);
        let bad = Args::parse("--ep-evict".split_whitespace().map(String::from));
        assert!(ServeConfig::default().apply_args(&bad).is_err());
    }

    #[test]
    fn replication_knobs_roundtrip_and_validation() {
        // defaults: no replica headroom, instantaneous swap, no prefetch —
        // byte-identical to the PR 5 behaviour
        let d = ServeConfig::default();
        assert!((d.ep_replica_slack - 1.0).abs() < 1e-12);
        assert_eq!(d.ep_migrate_budget, 0);
        assert!(!d.ep_prefetch);

        let p = write_tmp(
            "ep_migrate.json",
            r#"{"admission":"footprint","ep":{"n_gpus":4},"ep_rebalance":2,
               "ep_replica_slack":1.5,"ep_migrate_budget":3,"ep_prefetch":true}"#,
        );
        let cfg = ServeConfig::from_json_file(&p).unwrap();
        assert!((cfg.ep_replica_slack - 1.5).abs() < 1e-12);
        assert_eq!(cfg.ep_migrate_budget, 3);
        assert!(cfg.ep_prefetch);

        // slack below 1 / non-finite fails loudly
        for slack in [0.5f64, 0.0, -1.0, f64::NAN, f64::INFINITY] {
            let cfg = ServeConfig { ep_replica_slack: slack, ..ServeConfig::default() };
            let err = cfg.validate().unwrap_err();
            assert!(format!("{err:#}").contains("ep_replica_slack"), "{err:#}");
        }
        // replica headroom without an EP topology is meaningless
        let bad = write_tmp("ep_slack_bad.json", r#"{"ep_replica_slack":2.0}"#);
        assert!(ServeConfig::from_json_file(&bad).is_err());
        // migration without the rebalance clock has nothing to ride
        let bad = write_tmp(
            "ep_mig_bad.json",
            r#"{"admission":"footprint","ep":{"n_gpus":2},"ep_migrate_budget":2}"#,
        );
        let err = ServeConfig::from_json_file(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("ep-rebalance"), "{err:#}");
        // prefetch without a migration budget cannot schedule anything
        let bad = write_tmp(
            "ep_pref_bad.json",
            r#"{"admission":"footprint","ep":{"n_gpus":2},"ep_rebalance":2,
               "ep_prefetch":true}"#,
        );
        let err = ServeConfig::from_json_file(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("ep-migrate-budget"), "{err:#}");

        // CLI spellings
        let args = Args::parse(
            "--admission footprint --ep-gpus 4 --ep-rebalance 2 \
             --ep-replica-slack 2.0 --ep-migrate-budget 3 --ep-prefetch"
                .split_whitespace()
                .map(String::from),
        );
        let cfg = ServeConfig::default().apply_args(&args).unwrap();
        assert!((cfg.ep_replica_slack - 2.0).abs() < 1e-12);
        assert_eq!(cfg.ep_migrate_budget, 3);
        assert!(cfg.ep_prefetch);
        let bad = Args::parse(
            "--ep-gpus 2 --ep-replica-slack 0.5".split_whitespace().map(String::from),
        );
        assert!(ServeConfig::default().apply_args(&bad).is_err());
    }

    #[test]
    fn prefix_cache_knobs_roundtrip_and_validation() {
        // defaults: cache off, a sane minimum prefix
        let d = ServeConfig::default();
        assert_eq!(d.prefix_cache_mb, 0);
        assert_eq!(d.prefix_min_tokens, 8);

        let p = write_tmp(
            "prefix.json",
            r#"{"prefix_cache_mb":64,"prefix_min_tokens":4}"#,
        );
        let cfg = ServeConfig::from_json_file(&p).unwrap();
        assert_eq!(cfg.prefix_cache_mb, 64);
        assert_eq!(cfg.prefix_min_tokens, 4);

        // a zero minimum would admit empty prefixes that cannot restore
        let bad = write_tmp("prefix_bad.json", r#"{"prefix_min_tokens":0}"#);
        let err = ServeConfig::from_json_file(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("prefix_min_tokens"), "{err:#}");

        // CLI spellings
        let args = Args::parse(
            "--prefix-cache-mb 128 --prefix-min-tokens 6"
                .split_whitespace()
                .map(String::from),
        );
        let cfg = ServeConfig::default().apply_args(&args).unwrap();
        assert_eq!(cfg.prefix_cache_mb, 128);
        assert_eq!(cfg.prefix_min_tokens, 6);
        let bad = Args::parse(
            "--prefix-min-tokens 0".split_whitespace().map(String::from),
        );
        assert!(ServeConfig::default().apply_args(&bad).is_err());
    }

    #[test]
    fn chunk_shared_selection_roundtrip_and_validation() {
        // default: exact per-position routing (byte-identical outputs)
        assert!(!ServeConfig::default().chunk_shared_selection);

        let p = write_tmp(
            "shared_sel.json",
            r#"{"prefill_chunk":8,"chunk_shared_selection":true}"#,
        );
        let cfg = ServeConfig::from_json_file(&p).unwrap();
        assert!(cfg.chunk_shared_selection);
        assert_eq!(cfg.prefill_chunk, 8);

        // shared selection without chunked prefill is a config error
        let bad = write_tmp("shared_sel_bad.json", r#"{"chunk_shared_selection":true}"#);
        let err = ServeConfig::from_json_file(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("chunk-shared-selection"), "{err:#}");

        // CLI spellings
        let args = Args::parse(
            "--prefill-chunk 16 --chunk-shared-selection"
                .split_whitespace()
                .map(String::from),
        );
        let cfg = ServeConfig::default().apply_args(&args).unwrap();
        assert!(cfg.chunk_shared_selection);
        let bad = Args::parse(
            "--chunk-shared-selection".split_whitespace().map(String::from),
        );
        assert!(ServeConfig::default().apply_args(&bad).is_err());
    }

    #[test]
    fn fleet_knobs_roundtrip_and_validation() {
        use crate::fleet::AffinityMode;
        // defaults: single loop, class affinity, no backpressure spilling
        let d = ServeConfig::default();
        assert_eq!(d.fleet_replicas, 1);
        assert_eq!(d.fleet_affinity, AffinityMode::Class);
        assert_eq!(d.fleet_high_water, 0);
        assert_eq!(d.fleet_probe_every, 16);

        let p = write_tmp(
            "fleet.json",
            r#"{"fleet_replicas":3,"fleet_affinity":"round-robin",
               "fleet_high_water":8,"fleet_probe_every":4}"#,
        );
        let cfg = ServeConfig::from_json_file(&p).unwrap();
        assert_eq!(cfg.fleet_replicas, 3);
        assert_eq!(cfg.fleet_affinity, AffinityMode::RoundRobin);
        assert_eq!(cfg.fleet_high_water, 8);
        assert_eq!(cfg.fleet_probe_every, 4);

        // zero replicas cannot serve anything
        let bad = write_tmp("fleet_bad.json", r#"{"fleet_replicas":0}"#);
        let err = ServeConfig::from_json_file(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("fleet_replicas"), "{err:#}");
        // backpressure spilling with one replica has nowhere to spill
        let bad = write_tmp("fleet_bad2.json", r#"{"fleet_high_water":4}"#);
        let err = ServeConfig::from_json_file(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("fleet-replicas"), "{err:#}");
        // a zero probe clock never probes
        let bad = write_tmp("fleet_bad3.json", r#"{"fleet_probe_every":0}"#);
        assert!(ServeConfig::from_json_file(&bad).is_err());
        // unknown routing mode fails loudly
        let bad = write_tmp("fleet_bad4.json", r#"{"fleet_affinity":"random"}"#);
        assert!(ServeConfig::from_json_file(&bad).is_err());

        // CLI spellings
        let args = Args::parse(
            "--fleet-replicas 2 --fleet-affinity class --fleet-high-water 6 \
             --fleet-probe-every 8"
                .split_whitespace()
                .map(String::from),
        );
        let cfg = ServeConfig::default().apply_args(&args).unwrap();
        assert_eq!(cfg.fleet_replicas, 2);
        assert_eq!(cfg.fleet_affinity, AffinityMode::Class);
        assert_eq!(cfg.fleet_high_water, 6);
        assert_eq!(cfg.fleet_probe_every, 8);
        let bad =
            Args::parse("--fleet-high-water 4".split_whitespace().map(String::from));
        assert!(ServeConfig::default().apply_args(&bad).is_err());
    }

    #[test]
    fn placement_parsing() {
        assert_eq!(parse_placement("contiguous").unwrap(), PlacementKind::Contiguous);
        assert_eq!(parse_placement("round-robin").unwrap(), PlacementKind::RoundRobin);
        assert_eq!(parse_placement("random:5").unwrap(), PlacementKind::Random(5));
        assert!(parse_placement("diagonal").is_err());
    }
}
