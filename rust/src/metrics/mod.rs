//! Serving metrics: expert-activation accounting, latency histograms, OTPS,
//! and report emission for the benches.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Streaming mean/min/max accumulator.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub n: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn add(&mut self, v: f64) {
        if self.n == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.n += 1;
        self.sum += v;
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    /// Fold another accumulator into this one: identical to having added
    /// the other side's samples here one by one. Empty sides are neutral —
    /// min/max only combine when both sides actually saw samples.
    pub fn merge(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        self.n += other.n;
        self.sum += other.sum;
    }
}

/// Fixed-boundary latency histogram (µs buckets, log-spaced).
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    bounds_us: Vec<f64>,
    counts: Vec<u64>,
    summary: Summary,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        // 1µs … ~100s, quarter-decade steps
        let bounds_us: Vec<f64> = (0..33).map(|i| 10f64.powf(i as f64 / 4.0)).collect();
        let counts = vec![0; bounds_us.len() + 1];
        LatencyHistogram { bounds_us, counts, summary: Summary::default() }
    }
}

impl LatencyHistogram {
    pub fn record_seconds(&mut self, s: f64) {
        let us = s * 1e6;
        let idx = self.bounds_us.partition_point(|&b| b < us);
        self.counts[idx] += 1;
        self.summary.add(us);
    }

    pub fn count(&self) -> u64 {
        self.summary.n
    }

    pub fn mean_us(&self) -> f64 {
        self.summary.mean()
    }

    /// Fold another histogram into this one (bucket-wise; both sides use
    /// the fixed default boundaries, asserted here). Quantiles of the
    /// merge weight every underlying sample, exactly as if all had been
    /// recorded into one histogram.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        assert_eq!(
            self.bounds_us, other.bounds_us,
            "histogram bucket boundaries diverged"
        );
        for (c, &o) in self.counts.iter_mut().zip(&other.counts) {
            *c += o;
        }
        self.summary.merge(&other.summary);
    }

    /// Approximate quantile in seconds (bucket boundaries are µs).
    pub fn quantile_seconds(&self, q: f64) -> f64 {
        self.quantile_us(q) * 1e-6
    }

    /// Approximate quantile from bucket boundaries.
    pub fn quantile_us(&self, q: f64) -> f64 {
        let total = self.summary.n;
        if total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return if i == 0 {
                    self.bounds_us[0]
                } else if i >= self.bounds_us.len() {
                    self.summary.max
                } else {
                    self.bounds_us[i]
                };
            }
        }
        self.summary.max
    }
}

/// Everything a serving run reports — the benches print these as the
/// paper-table rows.
#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    /// Generated output tokens produced (committed, not speculative-
    /// rejected). Prompt tokens are NEVER counted here — they land in
    /// [`ServeMetrics::tokens_prompt`], so OTPS can't inflate on long
    /// prompts.
    pub tokens_out: u64,
    /// Prompt tokens consumed by prefill (one-token steps and chunks).
    pub tokens_prompt: u64,
    /// Chunked-prefill artifact invocations (0 under one-token prefill).
    pub prefill_forwards: u64,
    /// Prompt tokens consumed per serving step, over steps that prefilled
    /// via chunks (the "prefill-tokens-per-step" TTFT lever).
    pub prefill_tokens_per_step: Summary,
    /// Requests completed.
    pub requests_done: u64,
    /// Simulated time (memsim) spent, seconds. The serve loop mirrors this
    /// from `cost::Ledger::clock()` after every posted charge — the ledger
    /// is the single writer to the sim clock; nothing else accumulates here
    /// (the `record_*` helpers deliberately do not touch it).
    pub sim_seconds: f64,
    /// Per-phase sim-second attribution, mirrored from the cost ledger
    /// (`cost::Phase`). Per replica these sum to `sim_seconds` (the ledger
    /// conservation invariant); after a fleet [`ServeMetrics::merge`] they
    /// sum to the *total* busy seconds across replicas while `sim_seconds`
    /// holds the makespan — so the sum exceeds the clock by design there.
    ///
    /// Decode forwards (plain steps; EP steps).
    pub time_decode_s: f64,
    /// Speculative verify forwards plus model-draft forwards.
    pub time_spec_s: f64,
    /// Prefill: chunk forwards and fused waves.
    pub time_prefill_s: f64,
    /// Migration interconnect backlog drained into step time.
    pub time_migration_s: f64,
    /// Idle gap-advances (`ServeLoop::advance_idle_to`).
    pub time_overhead_s: f64,
    /// Wall-clock spent in PJRT execution, seconds.
    pub wall_seconds: f64,
    /// Decode steps taken.
    pub steps: u64,
    /// Per-layer activated-expert summaries (mini-model layer index).
    pub activated: Vec<Summary>,
    /// Max per-GPU load summary (EP runs).
    pub max_gpu_load: Summary,
    /// Per-GPU activated-expert load histogram (EP runs): one sample per
    /// layer per forward, indexed by GPU. Sized on first record.
    pub gpu_loads: Vec<Summary>,
    /// ∫ MaxLoad dt over simulated time (Σ step MaxLoad × step seconds) —
    /// the straggler exposure the EP serve bench compares placements by.
    pub gpu_load_integral: f64,
    /// Rows preempted back to the queue by footprint-aware eviction.
    pub evictions: u64,
    /// Placement rebalances adopted (`--ep-rebalance`; candidates that did
    /// not improve expected MaxLoad are discarded and not counted).
    pub rebalances: u64,
    /// Expected-MaxLoad improvement of each adopted rebalance (before −
    /// after under the tracked mix weights; positive by construction).
    pub rebalance_delta: Summary,
    /// Incremental migration plans adopted (`--ep-migrate-budget`; plans
    /// whose straggler saving did not beat the interconnect charge are
    /// discarded and not counted).
    pub migrations: u64,
    /// Operations (copies + drops) per adopted migration plan — `max` is
    /// per-step-bounded by the configured budget.
    pub migration_ops: Summary,
    /// Total expert-weight bytes moved by adopted migrations.
    pub migration_bytes: f64,
    /// Simulated interconnect seconds of migration traffic actually drained
    /// into step time (the backlog charge, see `ServeLoop::charge_step`).
    pub migration_seconds: f64,
    /// Migration plans adopted from the prefetch path (`--ep-prefetch`,
    /// queued-mix weights only; a subset of `migrations`).
    pub prefetches: u64,
    /// Speculative: proposed / accepted bonus counts.
    pub spec_proposed: u64,
    pub spec_accepted: u64,
    /// Per-row draft depth of every verify-cycle rider (depth-0 riders
    /// included) — the adaptive controller's observable.
    pub spec_depth: Summary,
    /// Per-traffic-class acceptance-rate distribution: one sample per
    /// drafting row per verify cycle (n_accepted / depth).
    pub spec_accept_by_class: BTreeMap<String, Summary>,
    /// Steps where speculation was desired (spec_len > 0, decode rows
    /// live) but no verify cycle ran — the legacy batch-global gate
    /// stalled it, or every row's adaptive depth collapsed to 0.
    pub spec_stalled_steps: u64,
    /// Per-step simulated latency histogram.
    pub step_latency: LatencyHistogram,
    /// Per-step wall-clock latency histogram (PJRT execution cadence).
    pub wall_step_latency: LatencyHistogram,
    /// Sim-time from submission to first committed token, per request.
    pub ttft: Summary,
    /// TTFT tail distribution (p50/p95/p99 — means alone hide tail
    /// latency; the per-request values also land in [`ServeMetrics::ttft`]).
    pub ttft_hist: LatencyHistogram,
    /// TTFT per admission priority class ([`ServeMetrics::record_ttft`]).
    pub ttft_by_class: BTreeMap<u32, Summary>,
    /// Sim-time spent queued before slot admission, per request.
    pub queue_wait: Summary,
    /// Queue-wait tail distribution (p50/p95/p99).
    pub queue_wait_hist: LatencyHistogram,
    /// Admission-queue depth sampled once per serving step.
    pub queue_depth: Summary,
    /// Requests rejected at submit time by queue backpressure.
    pub queue_rejected: u64,
    /// Requests whose first token committed after their TTFT deadline.
    pub deadline_misses: u64,
    /// Requests that carried a TTFT deadline (miss-rate denominator).
    pub deadline_total: u64,
    /// Predicted expert-set overlap of each footprint-admitted request
    /// against the running batch (admission-time co-scheduling gauge).
    pub footprint_overlap: Summary,
    /// Requests admitted while other sequences were already mid-flight —
    /// the continuous-batching "late joiner" count (always 0 under
    /// batch-at-a-time serving of uniform-length requests).
    pub admitted_in_flight: u64,
    /// Prefix-cache lookups whose prompt extended a cached entry (slab
    /// restored, suffix-only prefill).
    pub prefix_hits: u64,
    /// Prefix-cache lookups that found no usable entry.
    pub prefix_misses: u64,
    /// Slabs admitted into the prefix cache (finish/eviction offers that
    /// passed the min-tokens/budget gates).
    pub prefix_inserts: u64,
    /// Slabs LRU-evicted from the prefix cache to fit the VRAM budget.
    pub prefix_evictions: u64,
    /// Tokens currently resident in the prefix cache (gauge, mirrored at
    /// every cache-touching operation).
    pub prefix_cached_tokens: u64,
    /// Prompt positions satisfied by cache restore instead of a prefill
    /// forward. The restore-vs-recompute split: restored positions never
    /// land in [`ServeMetrics::tokens_prompt`], which keeps counting only
    /// positions actually forwarded.
    pub prefill_restored_tokens: u64,
    /// Eviction-resume admissions whose history was still cached — the
    /// recompute was skipped entirely or partially.
    pub resume_restores: u64,
    /// Eviction-resume admissions that re-prefilled their whole history
    /// because the offered slab had already been evicted (counted only
    /// when the cache is enabled; with the cache off every resume
    /// recomputes and neither counter moves).
    pub resume_recomputes: u64,
    /// Fused prefill waves executed: one wave = every co-prefilling row's
    /// chunk invocation in one serving-step round, charged ONCE over the
    /// unioned activations and total token count (0 under sequential
    /// per-invocation charging and under one-token prefill).
    pub prefill_waves: u64,
    /// Chunk invocations fused per wave — the weight-stream amortization
    /// factor (mean/max double as the rows-per-wave histogram).
    pub prefill_rows_per_wave: Summary,
    /// Per-layer weight streams saved by wave fusion: Σ over waves of
    /// (fused invocations − 1). Each saved stream is one full per-layer
    /// weight pass the sequential walk would have paid again.
    pub prefill_streams_saved: u64,
    /// Shared-selection routing distortion (`--chunk-shared-selection`):
    /// token-match fraction of a lossy run against its exact baseline, one
    /// sample per harness comparison ([`ServeMetrics::record_shared_selection_fidelity`]).
    /// Empty when sharing is off — the derived gauges then report exactly
    /// zero distortion, never NaN.
    pub shared_selection_fidelity: Summary,
}

impl ServeMetrics {
    pub fn new(n_layers: usize) -> ServeMetrics {
        ServeMetrics { activated: vec![Summary::default(); n_layers], ..Default::default() }
    }

    /// Record one decode step's counters and its latency sample. `sim_s`
    /// feeds the per-step latency histogram only — the sim clock itself is
    /// owned by the cost ledger and mirrored into
    /// [`ServeMetrics::sim_seconds`] by the serve loop.
    pub fn record_step(&mut self, activated_per_layer: &[usize], sim_s: f64, tokens: u64) {
        assert_eq!(activated_per_layer.len(), self.activated.len());
        for (s, &a) in self.activated.iter_mut().zip(activated_per_layer) {
            s.add(a as f64);
        }
        self.step_latency.record_seconds(sim_s);
        self.steps += 1;
        self.tokens_out += tokens;
    }

    /// Record one chunked-prefill forward: `prompt_tokens` prompt positions
    /// advanced in a single target invocation. Contributes activation
    /// summaries like a decode forward but counts toward
    /// `tokens_prompt`/`prefill_forwards`, never `tokens_out`/`steps` — and
    /// stays out of `step_latency`, which samples decode forwards (several
    /// fractional chunk entries per serving step would drag the per-step
    /// quantiles low exactly on the prefill-heavy workloads they observe).
    /// The simulated cost is charged on the ledger by the caller, never
    /// here.
    pub fn record_prefill(&mut self, activated_per_layer: &[usize], prompt_tokens: u64) {
        assert_eq!(activated_per_layer.len(), self.activated.len());
        for (s, &a) in self.activated.iter_mut().zip(activated_per_layer) {
            s.add(a as f64);
        }
        self.prefill_forwards += 1;
        self.tokens_prompt += prompt_tokens;
    }

    /// Record one fused prefill wave: `fused_invocations` chunk forwards
    /// charged as a single amortized ledger pass. Rides on top of the
    /// per-invocation [`ServeMetrics::record_prefill`] calls (which carry
    /// the token/activation accounting), owning only the fusion gauges —
    /// the wave's simulated cost is posted on the ledger by the caller.
    pub fn record_prefill_wave(&mut self, fused_invocations: usize) {
        self.prefill_waves += 1;
        self.prefill_rows_per_wave.add(fused_invocations as f64);
        self.prefill_streams_saved += fused_invocations.saturating_sub(1) as u64;
    }

    /// Record one shared-selection fidelity comparison (token-match
    /// fraction in `[0, 1]` from `coordinator::fidelity::compare`).
    pub fn record_shared_selection_fidelity(&mut self, token_match: f64) {
        assert!(
            token_match.is_finite(),
            "shared-selection fidelity must be a finite token-match fraction"
        );
        self.shared_selection_fidelity.add(token_match);
    }

    /// Shared-selection token-match fraction: 1.0 (no distortion) until a
    /// comparison is recorded — sharing off must read as exactly lossless.
    pub fn shared_selection_token_match(&self) -> f64 {
        if self.shared_selection_fidelity.n == 0 {
            1.0
        } else {
            self.shared_selection_fidelity.mean()
        }
    }

    /// Shared-selection accuracy drop in percentage points (≥ 0; exactly
    /// 0.0 when sharing is off or lossless).
    pub fn shared_selection_drop_pts(&self) -> f64 {
        (1.0 - self.shared_selection_token_match()) * 100.0
    }

    /// Prompt tokens prefilled per simulated second — the prefill-axis
    /// throughput the fused-wave bench compares charging modes by.
    pub fn prompt_tokens_per_s(&self) -> f64 {
        if self.sim_seconds <= 0.0 {
            return 0.0;
        }
        self.tokens_prompt as f64 / self.sim_seconds
    }

    /// Record one request's first-token latency: the aggregate summary,
    /// the tail histogram, its priority class, and — when it carried a
    /// deadline — whether the deadline was met.
    pub fn record_ttft(&mut self, seconds: f64, class: u32, deadline_missed: Option<bool>) {
        self.ttft.add(seconds);
        self.ttft_hist.record_seconds(seconds);
        self.ttft_by_class.entry(class).or_default().add(seconds);
        if let Some(missed) = deadline_missed {
            self.deadline_total += 1;
            if missed {
                self.deadline_misses += 1;
            }
        }
    }

    /// Record one forward's per-layer per-GPU loads (EP accounting). The
    /// gauge vector is sized to the topology on first use so metrics stay
    /// topology-agnostic at construction.
    pub fn record_gpu_loads(&mut self, loads: &[usize]) {
        if self.gpu_loads.len() < loads.len() {
            self.gpu_loads.resize(loads.len(), Summary::default());
        }
        for (s, &l) in self.gpu_loads.iter_mut().zip(loads) {
            s.add(l as f64);
        }
    }

    /// Record one drafting row's acceptance rate for one verify cycle,
    /// keyed by its traffic class.
    pub fn record_spec_accept(&mut self, class: &str, rate: f64) {
        self.spec_accept_by_class.entry(class.to_string()).or_default().add(rate);
    }

    /// Record one request's queue wait (submission → slot admission).
    pub fn record_queue_wait(&mut self, seconds: f64) {
        self.queue_wait.add(seconds);
        self.queue_wait_hist.record_seconds(seconds);
    }

    /// Fraction of deadlined requests that missed.
    pub fn deadline_miss_rate(&self) -> f64 {
        if self.deadline_total == 0 {
            return 0.0;
        }
        self.deadline_misses as f64 / self.deadline_total as f64
    }

    /// Simulated output tokens per second — the paper's OTPS.
    pub fn otps(&self) -> f64 {
        if self.sim_seconds <= 0.0 {
            return 0.0;
        }
        self.tokens_out as f64 / self.sim_seconds
    }

    /// Mean activated experts per layer, averaged over layers.
    pub fn mean_activated(&self) -> f64 {
        if self.activated.is_empty() {
            return 0.0;
        }
        self.activated.iter().map(Summary::mean).sum::<f64>() / self.activated.len() as f64
    }

    /// Speculative acceptance rate.
    pub fn acceptance_rate(&self) -> f64 {
        if self.spec_proposed == 0 {
            return 0.0;
        }
        self.spec_accepted as f64 / self.spec_proposed as f64
    }

    /// Fold another replica's metrics into this one — the fleet rollup.
    ///
    /// Merge semantics by field kind:
    /// - **counters** (token/request/step tallies, cache and EP event
    ///   counts) sum;
    /// - **distributions** ([`Summary`] accumulators and
    ///   [`LatencyHistogram`]s) merge sample-exactly, so aggregate means
    ///   and quantiles weight every replica's samples;
    /// - **clocks** (`sim_seconds`, `wall_seconds`) take the MAX: replicas
    ///   run concurrently, so the fleet makespan is the slowest replica's
    ///   clock and aggregate OTPS is Σ tokens / max clock — summing clocks
    ///   would report serial time and understate fleet throughput N-fold;
    /// - **keyed maps** and **per-index gauge vectors** merge entrywise
    ///   (vectors resize to the longer side).
    ///
    /// `other` is destructured exhaustively (no `..` rest pattern): adding
    /// a field to [`ServeMetrics`] without deciding its merge rule is a
    /// compile error, so no field can silently drop out of the rollup.
    pub fn merge(&mut self, other: &ServeMetrics) {
        let ServeMetrics {
            tokens_out,
            tokens_prompt,
            prefill_forwards,
            prefill_tokens_per_step,
            requests_done,
            sim_seconds,
            time_decode_s,
            time_spec_s,
            time_prefill_s,
            time_migration_s,
            time_overhead_s,
            wall_seconds,
            steps,
            activated,
            max_gpu_load,
            gpu_loads,
            gpu_load_integral,
            evictions,
            rebalances,
            rebalance_delta,
            migrations,
            migration_ops,
            migration_bytes,
            migration_seconds,
            prefetches,
            spec_proposed,
            spec_accepted,
            spec_depth,
            spec_accept_by_class,
            spec_stalled_steps,
            step_latency,
            wall_step_latency,
            ttft,
            ttft_hist,
            ttft_by_class,
            queue_wait,
            queue_wait_hist,
            queue_depth,
            queue_rejected,
            deadline_misses,
            deadline_total,
            footprint_overlap,
            admitted_in_flight,
            prefix_hits,
            prefix_misses,
            prefix_inserts,
            prefix_evictions,
            prefix_cached_tokens,
            prefill_restored_tokens,
            resume_restores,
            resume_recomputes,
            prefill_waves,
            prefill_rows_per_wave,
            prefill_streams_saved,
            shared_selection_fidelity,
        } = other;

        self.tokens_out += tokens_out;
        self.tokens_prompt += tokens_prompt;
        self.prefill_forwards += prefill_forwards;
        self.prefill_tokens_per_step.merge(prefill_tokens_per_step);
        self.requests_done += requests_done;
        self.sim_seconds = self.sim_seconds.max(*sim_seconds);
        // phase attribution SUMS across replicas (total busy seconds by
        // phase), while the clock maxes (makespan) — see the field docs
        self.time_decode_s += time_decode_s;
        self.time_spec_s += time_spec_s;
        self.time_prefill_s += time_prefill_s;
        self.time_migration_s += time_migration_s;
        self.time_overhead_s += time_overhead_s;
        self.wall_seconds = self.wall_seconds.max(*wall_seconds);
        self.steps += steps;
        merge_summary_vec(&mut self.activated, activated);
        self.max_gpu_load.merge(max_gpu_load);
        merge_summary_vec(&mut self.gpu_loads, gpu_loads);
        self.gpu_load_integral += gpu_load_integral;
        self.evictions += evictions;
        self.rebalances += rebalances;
        self.rebalance_delta.merge(rebalance_delta);
        self.migrations += migrations;
        self.migration_ops.merge(migration_ops);
        self.migration_bytes += migration_bytes;
        self.migration_seconds += migration_seconds;
        self.prefetches += prefetches;
        self.spec_proposed += spec_proposed;
        self.spec_accepted += spec_accepted;
        self.spec_depth.merge(spec_depth);
        for (class, s) in spec_accept_by_class {
            self.spec_accept_by_class.entry(class.clone()).or_default().merge(s);
        }
        self.spec_stalled_steps += spec_stalled_steps;
        self.step_latency.merge(step_latency);
        self.wall_step_latency.merge(wall_step_latency);
        self.ttft.merge(ttft);
        self.ttft_hist.merge(ttft_hist);
        for (class, s) in ttft_by_class {
            self.ttft_by_class.entry(*class).or_default().merge(s);
        }
        self.queue_wait.merge(queue_wait);
        self.queue_wait_hist.merge(queue_wait_hist);
        self.queue_depth.merge(queue_depth);
        self.queue_rejected += queue_rejected;
        self.deadline_misses += deadline_misses;
        self.deadline_total += deadline_total;
        self.footprint_overlap.merge(footprint_overlap);
        self.admitted_in_flight += admitted_in_flight;
        self.prefix_hits += prefix_hits;
        self.prefix_misses += prefix_misses;
        self.prefix_inserts += prefix_inserts;
        self.prefix_evictions += prefix_evictions;
        self.prefix_cached_tokens += prefix_cached_tokens;
        self.prefill_restored_tokens += prefill_restored_tokens;
        self.resume_restores += resume_restores;
        self.resume_recomputes += resume_recomputes;
        self.prefill_waves += prefill_waves;
        self.prefill_rows_per_wave.merge(prefill_rows_per_wave);
        self.prefill_streams_saved += prefill_streams_saved;
        self.shared_selection_fidelity.merge(shared_selection_fidelity);
    }

    /// JSON dump for reports.
    pub fn to_json(&self) -> Json {
        let mut m: BTreeMap<String, Json> = BTreeMap::new();
        m.insert("tokens_out".into(), Json::num(self.tokens_out as f64));
        m.insert("tokens_prompt".into(), Json::num(self.tokens_prompt as f64));
        m.insert("prefill_forwards".into(), Json::num(self.prefill_forwards as f64));
        m.insert(
            "prefill_tokens_per_step".into(),
            Json::num(self.prefill_tokens_per_step.mean()),
        );
        m.insert("requests_done".into(), Json::num(self.requests_done as f64));
        m.insert("sim_seconds".into(), Json::num(self.sim_seconds));
        m.insert("time_decode_s".into(), Json::num(self.time_decode_s));
        m.insert("time_spec_s".into(), Json::num(self.time_spec_s));
        m.insert("time_prefill_s".into(), Json::num(self.time_prefill_s));
        m.insert("time_migration_s".into(), Json::num(self.time_migration_s));
        m.insert("time_overhead_s".into(), Json::num(self.time_overhead_s));
        m.insert("wall_seconds".into(), Json::num(self.wall_seconds));
        m.insert("steps".into(), Json::num(self.steps as f64));
        m.insert("otps".into(), Json::num(self.otps()));
        m.insert("mean_activated".into(), Json::num(self.mean_activated()));
        m.insert("acceptance_rate".into(), Json::num(self.acceptance_rate()));
        m.insert("spec_depth_mean".into(), Json::num(self.spec_depth.mean()));
        m.insert("spec_depth_max".into(), Json::num(self.spec_depth.max));
        m.insert(
            "spec_stalled_steps".into(),
            Json::num(self.spec_stalled_steps as f64),
        );
        let accept_classes: BTreeMap<String, Json> = self
            .spec_accept_by_class
            .iter()
            .map(|(c, s)| (c.clone(), Json::num(s.mean())))
            .collect();
        m.insert("spec_accept_by_class".into(), Json::Obj(accept_classes));
        m.insert("max_gpu_load_mean".into(), Json::num(self.max_gpu_load.mean()));
        m.insert("gpu_load_integral".into(), Json::num(self.gpu_load_integral));
        m.insert(
            "gpu_load_mean_by_gpu".into(),
            Json::Arr(self.gpu_loads.iter().map(|s| Json::num(s.mean())).collect()),
        );
        m.insert("evictions".into(), Json::num(self.evictions as f64));
        m.insert("rebalances".into(), Json::num(self.rebalances as f64));
        m.insert(
            "rebalance_delta_mean".into(),
            Json::num(self.rebalance_delta.mean()),
        );
        m.insert("migrations".into(), Json::num(self.migrations as f64));
        m.insert("migration_ops_max".into(), Json::num(self.migration_ops.max));
        m.insert("migration_bytes".into(), Json::num(self.migration_bytes));
        m.insert("migration_seconds".into(), Json::num(self.migration_seconds));
        m.insert("prefetches".into(), Json::num(self.prefetches as f64));
        m.insert("p50_step_us".into(), Json::num(self.step_latency.quantile_us(0.5)));
        m.insert("p99_step_us".into(), Json::num(self.step_latency.quantile_us(0.99)));
        m.insert(
            "p50_wall_step_us".into(),
            Json::num(self.wall_step_latency.quantile_us(0.5)),
        );
        m.insert(
            "p99_wall_step_us".into(),
            Json::num(self.wall_step_latency.quantile_us(0.99)),
        );
        m.insert("ttft_mean_s".into(), Json::num(self.ttft.mean()));
        m.insert("ttft_max_s".into(), Json::num(self.ttft.max));
        m.insert("ttft_p50_s".into(), Json::num(self.ttft_hist.quantile_seconds(0.5)));
        m.insert("ttft_p95_s".into(), Json::num(self.ttft_hist.quantile_seconds(0.95)));
        m.insert("ttft_p99_s".into(), Json::num(self.ttft_hist.quantile_seconds(0.99)));
        m.insert("queue_wait_mean_s".into(), Json::num(self.queue_wait.mean()));
        m.insert(
            "queue_wait_p50_s".into(),
            Json::num(self.queue_wait_hist.quantile_seconds(0.5)),
        );
        m.insert(
            "queue_wait_p95_s".into(),
            Json::num(self.queue_wait_hist.quantile_seconds(0.95)),
        );
        m.insert(
            "queue_wait_p99_s".into(),
            Json::num(self.queue_wait_hist.quantile_seconds(0.99)),
        );
        m.insert("queue_depth_mean".into(), Json::num(self.queue_depth.mean()));
        m.insert("queue_depth_max".into(), Json::num(self.queue_depth.max));
        m.insert("queue_rejected".into(), Json::num(self.queue_rejected as f64));
        m.insert("deadline_misses".into(), Json::num(self.deadline_misses as f64));
        m.insert("deadline_total".into(), Json::num(self.deadline_total as f64));
        m.insert("deadline_miss_rate".into(), Json::num(self.deadline_miss_rate()));
        m.insert(
            "footprint_overlap_mean".into(),
            Json::num(self.footprint_overlap.mean()),
        );
        let classes: BTreeMap<String, Json> = self
            .ttft_by_class
            .iter()
            .map(|(c, s)| (c.to_string(), Json::num(s.mean())))
            .collect();
        m.insert("ttft_mean_s_by_class".into(), Json::Obj(classes));
        m.insert(
            "admitted_in_flight".into(),
            Json::num(self.admitted_in_flight as f64),
        );
        m.insert("prefix_hits".into(), Json::num(self.prefix_hits as f64));
        m.insert("prefix_misses".into(), Json::num(self.prefix_misses as f64));
        m.insert("prefix_inserts".into(), Json::num(self.prefix_inserts as f64));
        m.insert("prefix_evictions".into(), Json::num(self.prefix_evictions as f64));
        m.insert(
            "prefix_cached_tokens".into(),
            Json::num(self.prefix_cached_tokens as f64),
        );
        m.insert(
            "prefill_restored_tokens".into(),
            Json::num(self.prefill_restored_tokens as f64),
        );
        m.insert("resume_restores".into(), Json::num(self.resume_restores as f64));
        m.insert("resume_recomputes".into(), Json::num(self.resume_recomputes as f64));
        m.insert("prefill_waves".into(), Json::num(self.prefill_waves as f64));
        m.insert(
            "prefill_rows_per_wave_mean".into(),
            Json::num(self.prefill_rows_per_wave.mean()),
        );
        m.insert(
            "prefill_rows_per_wave_max".into(),
            Json::num(self.prefill_rows_per_wave.max),
        );
        m.insert(
            "prefill_streams_saved".into(),
            Json::num(self.prefill_streams_saved as f64),
        );
        m.insert("prompt_tokens_per_s".into(), Json::num(self.prompt_tokens_per_s()));
        m.insert(
            "shared_selection_fidelity".into(),
            Json::num(self.shared_selection_token_match()),
        );
        m.insert(
            "shared_selection_drop_pts".into(),
            Json::num(self.shared_selection_drop_pts()),
        );
        Json::Obj(m)
    }
}

/// Element-wise merge of per-index gauge vectors (per-layer activation,
/// per-GPU load): the destination resizes to the longer side so no
/// replica's trailing entries are dropped.
fn merge_summary_vec(into: &mut Vec<Summary>, from: &[Summary]) {
    if into.len() < from.len() {
        into.resize(from.len(), Summary::default());
    }
    for (s, o) in into.iter_mut().zip(from) {
        s.merge(o);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_tracks_moments() {
        let mut s = Summary::default();
        for v in [2.0, 4.0, 6.0] {
            s.add(v);
        }
        assert_eq!(s.mean(), 4.0);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 6.0);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = LatencyHistogram::default();
        for i in 1..=1000 {
            h.record_seconds(i as f64 * 1e-6);
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile_us(0.5);
        let p99 = h.quantile_us(0.99);
        assert!(p50 <= p99);
        assert!((300.0..3000.0).contains(&p50), "{p50}");
    }

    #[test]
    fn serve_metrics_otps_and_activation() {
        let mut m = ServeMetrics::new(2);
        m.record_step(&[10, 20], 0.5, 8);
        m.record_step(&[30, 40], 0.5, 8);
        m.sim_seconds = 1.0; // ledger mirror (record_step never writes it)
        assert_eq!(m.otps(), 16.0);
        assert_eq!(m.mean_activated(), 25.0);
        assert_eq!(m.steps, 2);
    }

    #[test]
    fn prefill_counters_stay_out_of_otps() {
        // The throughput-inflation regression: prompt tokens must never
        // leak into tokens_out, even though prefill forwards advance the
        // sim clock and the activation summaries.
        let mut m = ServeMetrics::new(2);
        m.record_prefill(&[4, 6], 8);
        m.record_step(&[2, 2], 0.5, 3);
        m.sim_seconds = 1.0; // ledger mirror: prefill + decode charges
        assert_eq!(m.tokens_out, 3);
        assert_eq!(m.tokens_prompt, 8);
        assert_eq!(m.prefill_forwards, 1);
        assert_eq!(m.steps, 1, "prefill forwards are not decode steps");
        assert_eq!(m.otps(), 3.0, "OTPS counts generated tokens only");
        assert_eq!(m.activated[0].n, 2, "both forwards feed activation stats");
        let j = m.to_json();
        assert!(j.get("tokens_prompt").is_some());
        assert!(j.get("prefill_forwards").is_some());
        assert!(j.get("prefill_tokens_per_step").is_some());
    }

    #[test]
    fn acceptance_rate() {
        let mut m = ServeMetrics::new(1);
        m.spec_proposed = 10;
        m.spec_accepted = 7;
        assert!((m.acceptance_rate() - 0.7).abs() < 1e-12);
    }

    #[test]
    fn spec_depth_acceptance_and_stall_gauges() {
        let mut m = ServeMetrics::new(1);
        // one verify cycle: rows at depths 3, 1 and a depth-0 rider
        m.spec_depth.add(3.0);
        m.spec_depth.add(1.0);
        m.spec_depth.add(0.0);
        m.record_spec_accept("gpqa", 1.0);
        m.record_spec_accept("gpqa", 0.5);
        m.record_spec_accept("aime", 0.0);
        m.spec_stalled_steps = 4;
        assert!((m.spec_depth.mean() - 4.0 / 3.0).abs() < 1e-12);
        assert_eq!(m.spec_depth.max, 3.0);
        assert!((m.spec_accept_by_class["gpqa"].mean() - 0.75).abs() < 1e-12);
        assert_eq!(m.spec_accept_by_class["aime"].n, 1);
        let j = m.to_json();
        assert_eq!(
            j.get("spec_depth_mean").and_then(|v| v.as_f64()),
            Some(m.spec_depth.mean())
        );
        assert_eq!(j.get("spec_stalled_steps").and_then(|v| v.as_f64()), Some(4.0));
        let by_class = j.get("spec_accept_by_class").expect("class map dumped");
        assert_eq!(by_class.get("gpqa").and_then(|v| v.as_f64()), Some(0.75));
        assert_eq!(by_class.get("aime").and_then(|v| v.as_f64()), Some(0.0));
    }

    #[test]
    fn ep_serving_gauges_accumulate_and_dump() {
        let mut m = ServeMetrics::new(1);
        // per-GPU loads size lazily to the topology and track per sample
        m.record_gpu_loads(&[3, 1]);
        m.record_gpu_loads(&[1, 1]);
        assert_eq!(m.gpu_loads.len(), 2);
        assert_eq!(m.gpu_loads[0].mean(), 2.0);
        assert_eq!(m.gpu_loads[1].mean(), 1.0);
        m.gpu_load_integral += 3.0 * 0.5;
        m.evictions = 2;
        m.rebalances = 1;
        m.rebalance_delta.add(1.5);
        m.migrations = 2;
        m.migration_ops.add(3.0);
        m.migration_ops.add(1.0);
        m.migration_bytes = 2.0 * 44e6;
        m.migration_seconds = 2.0e-4;
        m.prefetches = 1;
        let j = m.to_json();
        assert_eq!(j.get("gpu_load_integral").and_then(|v| v.as_f64()), Some(1.5));
        assert_eq!(j.get("evictions").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(j.get("rebalances").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(
            j.get("rebalance_delta_mean").and_then(|v| v.as_f64()),
            Some(1.5)
        );
        assert_eq!(j.get("migrations").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(j.get("migration_ops_max").and_then(|v| v.as_f64()), Some(3.0));
        assert_eq!(j.get("migration_bytes").and_then(|v| v.as_f64()), Some(88e6));
        assert_eq!(j.get("migration_seconds").and_then(|v| v.as_f64()), Some(2.0e-4));
        assert_eq!(j.get("prefetches").and_then(|v| v.as_f64()), Some(1.0));
        let by_gpu = j.get("gpu_load_mean_by_gpu").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(by_gpu.len(), 2);
        assert_eq!(by_gpu[0].as_f64(), Some(2.0));
    }

    #[test]
    fn prefix_cache_gauges_dump() {
        let mut m = ServeMetrics::new(1);
        m.prefix_hits = 3;
        m.prefix_misses = 5;
        m.prefix_inserts = 4;
        m.prefix_evictions = 1;
        m.prefix_cached_tokens = 48;
        m.prefill_restored_tokens = 36;
        m.resume_restores = 2;
        m.resume_recomputes = 1;
        let j = m.to_json();
        assert_eq!(j.get("prefix_hits").and_then(|v| v.as_f64()), Some(3.0));
        assert_eq!(j.get("prefix_misses").and_then(|v| v.as_f64()), Some(5.0));
        assert_eq!(j.get("prefix_inserts").and_then(|v| v.as_f64()), Some(4.0));
        assert_eq!(j.get("prefix_evictions").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(j.get("prefix_cached_tokens").and_then(|v| v.as_f64()), Some(48.0));
        assert_eq!(
            j.get("prefill_restored_tokens").and_then(|v| v.as_f64()),
            Some(36.0)
        );
        assert_eq!(j.get("resume_restores").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(j.get("resume_recomputes").and_then(|v| v.as_f64()), Some(1.0));
    }

    #[test]
    fn prefill_wave_gauges_accumulate_and_dump() {
        let mut m = ServeMetrics::new(2);
        // two invocations ride one wave: per-invocation accounting at zero
        // cost each, the wave owns the fused charge
        m.record_prefill(&[4, 6], 8);
        m.record_prefill(&[2, 3], 5);
        m.record_prefill_wave(2);
        // a solo wave saves nothing
        m.record_prefill(&[1, 1], 2);
        m.record_prefill_wave(1);
        m.sim_seconds = 0.75; // ledger mirror of the two wave charges
        assert_eq!(m.prefill_waves, 2);
        assert_eq!(m.prefill_streams_saved, 1);
        assert!((m.prefill_rows_per_wave.mean() - 1.5).abs() < 1e-12);
        assert_eq!(m.prefill_rows_per_wave.max, 2.0);
        assert_eq!(m.tokens_prompt, 15);
        assert!((m.sim_seconds - 0.75).abs() < 1e-12);
        assert!((m.prompt_tokens_per_s() - 15.0 / 0.75).abs() < 1e-9);
        let j = m.to_json();
        assert_eq!(j.get("prefill_waves").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(
            j.get("prefill_rows_per_wave_mean").and_then(|v| v.as_f64()),
            Some(1.5)
        );
        assert_eq!(
            j.get("prefill_rows_per_wave_max").and_then(|v| v.as_f64()),
            Some(2.0)
        );
        assert_eq!(j.get("prefill_streams_saved").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(
            j.get("prompt_tokens_per_s").and_then(|v| v.as_f64()),
            Some(m.prompt_tokens_per_s())
        );
    }

    #[test]
    fn shared_selection_fidelity_defaults_lossless_and_never_nan() {
        // sharing off: no samples, yet the gauges read exactly lossless
        let m = ServeMetrics::new(1);
        assert_eq!(m.shared_selection_token_match(), 1.0);
        assert_eq!(m.shared_selection_drop_pts(), 0.0);
        let j = m.to_json();
        assert_eq!(
            j.get("shared_selection_fidelity").and_then(|v| v.as_f64()),
            Some(1.0)
        );
        assert_eq!(
            j.get("shared_selection_drop_pts").and_then(|v| v.as_f64()),
            Some(0.0)
        );

        // sharing on: harness-recorded comparisons average in
        let mut m = ServeMetrics::new(1);
        m.record_shared_selection_fidelity(0.9);
        m.record_shared_selection_fidelity(0.7);
        assert!((m.shared_selection_token_match() - 0.8).abs() < 1e-12);
        assert!((m.shared_selection_drop_pts() - 20.0).abs() < 1e-9);
        assert!(m.shared_selection_token_match().is_finite());
        assert!(m.shared_selection_drop_pts().is_finite());
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn shared_selection_fidelity_rejects_nan() {
        let mut m = ServeMetrics::new(1);
        m.record_shared_selection_fidelity(f64::NAN);
    }

    #[test]
    fn summary_merge_matches_interleaved_adds() {
        let mut a = Summary::default();
        let mut b = Summary::default();
        let mut whole = Summary::default();
        for (i, v) in [3.0, 9.0, 1.0, 4.0, 7.0].iter().enumerate() {
            if i % 2 == 0 { a.add(*v) } else { b.add(*v) }
            whole.add(*v);
        }
        a.merge(&b);
        assert_eq!((a.n, a.sum, a.min, a.max), (whole.n, whole.sum, whole.min, whole.max));
        // empty sides are neutral in both directions — min/max must not
        // pick up the zero-initialized fields of an empty accumulator
        let empty = Summary::default();
        let before = a.clone();
        a.merge(&empty);
        assert_eq!((a.n, a.min, a.max), (before.n, before.min, before.max));
        let mut fresh = Summary::default();
        fresh.merge(&a);
        assert_eq!((fresh.n, fresh.sum, fresh.min, fresh.max), (a.n, a.sum, a.min, a.max));
    }

    #[test]
    fn histogram_merge_matches_single_recorder() {
        let mut a = LatencyHistogram::default();
        let mut b = LatencyHistogram::default();
        let mut whole = LatencyHistogram::default();
        for i in 1..=100 {
            let s = i as f64 * 1e-5;
            if i % 2 == 0 { a.record_seconds(s) } else { b.record_seconds(s) }
            whole.record_seconds(s);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.mean_us(), whole.mean_us());
        for q in [0.5, 0.95, 0.99] {
            assert_eq!(a.quantile_us(q), whole.quantile_us(q), "q={q}");
        }
    }

    #[test]
    fn serve_metrics_merge_sums_counters_and_maxes_clocks() {
        // Two concurrent replicas: counters sum, distributions pool, and
        // the merged clock is the slowest replica (fleet makespan), so the
        // aggregate OTPS is Σ tokens / max clock.
        let mut a = ServeMetrics::new(2);
        a.record_step(&[10, 20], 1.0, 8);
        a.sim_seconds = 1.0;
        a.record_ttft(0.2, 0, Some(false));
        a.record_queue_wait(0.05);
        a.requests_done = 1;
        a.prefix_hits = 2;
        a.record_spec_accept("tplA", 1.0);
        let mut b = ServeMetrics::new(2);
        b.record_step(&[30, 40], 1.0, 4);
        b.record_step(&[30, 40], 1.0, 4);
        b.sim_seconds = 2.0;
        b.record_ttft(0.4, 1, Some(true));
        b.requests_done = 2;
        b.wall_seconds = 0.5;
        b.record_spec_accept("tplA", 0.5);
        b.record_spec_accept("tplB", 0.0);

        a.merge(&b);
        assert_eq!(a.tokens_out, 16);
        assert_eq!(a.steps, 3);
        assert_eq!(a.requests_done, 3);
        assert_eq!(a.prefix_hits, 2);
        // clocks: max(1.0, 2.0), not 3.0
        assert_eq!(a.sim_seconds, 2.0);
        assert_eq!(a.wall_seconds, 0.5);
        assert_eq!(a.otps(), 8.0, "aggregate OTPS = Σ tokens / makespan");
        // distributions pool every replica's samples
        assert_eq!(a.ttft.n, 2);
        assert!((a.ttft.mean() - 0.3).abs() < 1e-12);
        assert_eq!(a.ttft_hist.count(), 2);
        assert_eq!(a.queue_wait.n, 1);
        assert_eq!(a.step_latency.count(), 3);
        // keyed maps merge per key
        assert_eq!(a.ttft_by_class[&0].n, 1);
        assert_eq!(a.ttft_by_class[&1].n, 1);
        assert!((a.spec_accept_by_class["tplA"].mean() - 0.75).abs() < 1e-12);
        assert_eq!(a.spec_accept_by_class["tplB"].n, 1);
        // deadline accounting survives
        assert_eq!(a.deadline_total, 2);
        assert_eq!(a.deadline_misses, 1);
        // per-layer activation pools both replicas' forwards
        assert_eq!(a.activated[0].n, 3);
        assert_eq!(a.activated[0].max, 30.0);
        assert_eq!(a.mean_activated(), 25.0);
    }

    #[test]
    fn phase_time_fields_sum_in_merge_and_dump() {
        // Per replica the phase breakdown conserves the clock; the fleet
        // rollup SUMS phase seconds (total busy time by phase) while the
        // clock takes the makespan max.
        let mut a = ServeMetrics::new(1);
        a.sim_seconds = 1.0;
        a.time_decode_s = 0.6;
        a.time_spec_s = 0.25;
        a.time_prefill_s = 0.1;
        a.time_migration_s = 0.04;
        a.time_overhead_s = 0.01;
        let mut b = ServeMetrics::new(1);
        b.sim_seconds = 2.0;
        b.time_decode_s = 1.5;
        b.time_prefill_s = 0.5;
        a.merge(&b);
        assert_eq!(a.sim_seconds, 2.0, "clock is the makespan");
        assert!((a.time_decode_s - 2.1).abs() < 1e-12);
        assert!((a.time_spec_s - 0.25).abs() < 1e-12);
        assert!((a.time_prefill_s - 0.6).abs() < 1e-12);
        assert!((a.time_migration_s - 0.04).abs() < 1e-12);
        assert!((a.time_overhead_s - 0.01).abs() < 1e-12);
        let j = a.to_json();
        for (key, want) in [
            ("time_decode_s", a.time_decode_s),
            ("time_spec_s", a.time_spec_s),
            ("time_prefill_s", a.time_prefill_s),
            ("time_migration_s", a.time_migration_s),
            ("time_overhead_s", a.time_overhead_s),
        ] {
            assert_eq!(j.get(key).and_then(|v| v.as_f64()), Some(want), "{key}");
        }
    }

    #[test]
    fn serve_metrics_merge_resizes_gauge_vectors() {
        // A 4-GPU replica folds into a 2-GPU accumulator without dropping
        // the trailing GPUs (and layer-count mismatches likewise resize).
        let mut a = ServeMetrics::new(1);
        a.record_gpu_loads(&[3, 1]);
        let mut b = ServeMetrics::new(1);
        b.record_gpu_loads(&[1, 1, 5, 2]);
        a.merge(&b);
        assert_eq!(a.gpu_loads.len(), 4);
        assert_eq!(a.gpu_loads[0].mean(), 2.0);
        assert_eq!(a.gpu_loads[2].mean(), 5.0);
        assert_eq!(a.gpu_loads[2].n, 1);
    }

    #[test]
    fn json_dump_has_headline_fields() {
        let m = ServeMetrics::new(1);
        let j = m.to_json();
        assert!(j.get("otps").is_some());
        assert!(j.get("mean_activated").is_some());
        assert!(j.get("ttft_mean_s").is_some());
        assert!(j.get("queue_wait_mean_s").is_some());
        assert!(j.get("admitted_in_flight").is_some());
    }

    #[test]
    fn ttft_and_queue_wait_report_tail_quantiles() {
        // Means alone hide tails: 90 fast requests and one slow one must
        // show up in p99 but barely move p50 (with n = 91 the p99 rank is
        // 91, one past the 90 fast samples, so the straggler's bucket is
        // the one reported).
        let mut m = ServeMetrics::new(1);
        for _ in 0..90 {
            m.record_ttft(0.001, 0, None);
            m.record_queue_wait(0.0005);
        }
        m.record_ttft(2.0, 0, None);
        m.record_queue_wait(1.0);
        let p50 = m.ttft_hist.quantile_seconds(0.5);
        let p95 = m.ttft_hist.quantile_seconds(0.95);
        let p99 = m.ttft_hist.quantile_seconds(0.99);
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        assert!(p50 < 0.01, "p50 {p50} dragged up by the tail");
        assert!(p99 > 0.5, "p99 {p99} missed the straggler");
        let j = m.to_json();
        for key in [
            "ttft_p50_s",
            "ttft_p95_s",
            "ttft_p99_s",
            "queue_wait_p50_s",
            "queue_wait_p95_s",
            "queue_wait_p99_s",
        ] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
    }

    #[test]
    fn per_class_ttft_and_deadline_accounting() {
        let mut m = ServeMetrics::new(1);
        m.record_ttft(0.1, 0, None);
        m.record_ttft(0.3, 1, Some(false));
        m.record_ttft(0.5, 1, Some(true));
        assert_eq!(m.ttft.n, 3);
        assert!((m.ttft_by_class[&0].mean() - 0.1).abs() < 1e-12);
        assert!((m.ttft_by_class[&1].mean() - 0.4).abs() < 1e-12);
        assert_eq!(m.deadline_total, 2);
        assert_eq!(m.deadline_misses, 1);
        assert!((m.deadline_miss_rate() - 0.5).abs() < 1e-12);
        let j = m.to_json();
        assert!(j.get("ttft_mean_s_by_class").is_some());
        assert!(j.get("deadline_miss_rate").is_some());
    }

    #[test]
    fn queue_depth_and_rejection_gauges_dump() {
        let mut m = ServeMetrics::new(1);
        m.queue_depth.add(3.0);
        m.queue_depth.add(5.0);
        m.queue_rejected = 2;
        m.footprint_overlap.add(2.5);
        let j = m.to_json();
        assert_eq!(j.get("queue_depth_mean").and_then(|v| v.as_f64()), Some(4.0));
        assert_eq!(j.get("queue_depth_max").and_then(|v| v.as_f64()), Some(5.0));
        assert_eq!(j.get("queue_rejected").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(
            j.get("footprint_overlap_mean").and_then(|v| v.as_f64()),
            Some(2.5)
        );
    }

    #[test]
    fn serving_latency_counters_accumulate() {
        let mut m = ServeMetrics::new(1);
        m.ttft.add(0.25);
        m.ttft.add(0.75);
        m.queue_wait.add(0.1);
        m.admitted_in_flight += 3;
        m.wall_step_latency.record_seconds(1e-3);
        assert!((m.ttft.mean() - 0.5).abs() < 1e-12);
        assert_eq!(m.queue_wait.n, 1);
        assert_eq!(m.admitted_in_flight, 3);
        assert_eq!(m.wall_step_latency.count(), 1);
        let j = m.to_json();
        assert!(j.get("p99_wall_step_us").is_some());
    }
}
