//! Speculative decoding demo (§4 of the paper): gptoss-mini with the dense
//! draft model, speculation length 3, batch 4 — the paper's Figure 5
//! setting. Compares vanilla, batch-aware (Algorithm 2) and hierarchical
//! speculative-aware (Algorithm 4) selection on the same trace.
//!
//!   make artifacts && cargo run --release --example speculative

use anyhow::Result;

use xshare::config::ServeConfig;
use xshare::coordinator::{compare, Request, Scheduler};
use xshare::gen::{TraceDomain, TraceGenerator};
use xshare::model::MoeModel;
use xshare::runtime::{artifacts_root, Engine, Manifest};
use xshare::selection::PolicyKind;

fn main() -> Result<()> {
    let preset = "gptoss-mini";
    let manifest = Manifest::load(&artifacts_root().join(preset))?;
    let vocab = manifest.model.vocab;
    eprintln!("loading {preset} …");
    let mut model = MoeModel::new(Engine::load(manifest)?)?;

    let trace = TraceGenerator::new(vocab, 7).generate(&TraceDomain::standard_suite(), 8);
    let requests: Vec<Request> = trace
        .into_iter()
        .map(|t| {
            let mut prompt = t.prompt;
            prompt.truncate(10);
            let mut r = Request::new(t.id, prompt, 10);
            r.domain = t.domain;
            r
        })
        .collect();

    let cfg = ServeConfig {
        preset: preset.into(),
        batch_size: 4,
        spec_len: 3,
        ..Default::default()
    };

    println!("== speculative decoding, BS=4, L_s=3 (effective batch 16) ==");
    let mut baseline_outputs = None;
    for policy in ["vanilla", "batch:16:1", "spec:1:0:4"] {
        let mut c = cfg.clone();
        c.policy = PolicyKind::parse(policy).map_err(anyhow::Error::msg)?;
        let report = Scheduler::new(&mut model, c)?.run(requests.clone())?;
        let m = &report.metrics;
        let fidelity = match &baseline_outputs {
            None => {
                baseline_outputs = Some(report.outputs.clone());
                1.0
            }
            Some(base) => compare(base, &report.outputs).token_match,
        };
        println!(
            "{policy:<12} otps={:7.1}  activated/layer={:6.1}  accept={:4.1}%  fidelity={:5.1}%",
            m.otps(),
            m.mean_activated(),
            m.acceptance_rate() * 100.0,
            fidelity * 100.0
        );
    }
    println!("\nAlgorithm 4 (spec:1:0:4) exploits intra-request expert correlation:");
    println!("fewer activated experts than Algorithm 2 at the same fidelity level.");
    Ok(())
}
