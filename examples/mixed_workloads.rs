//! Heterogeneous-batch demo (§6.3 / Table 1 of the paper): a speculative
//! batch whose four requests come from four different datasets (GPQA,
//! AIME2025, MMLU-Pro, AA-LCR). Shows that the hierarchical selection of
//! Algorithm 4 keeps its advantage when requests are domain-diverse —
//! per-request budgets adapt to each request's own expert profile.
//!
//!   make artifacts && cargo run --release --example mixed_workloads

use anyhow::Result;

use xshare::config::ServeConfig;
use xshare::coordinator::{compare, Request, Scheduler};
use xshare::gen::TraceGenerator;
use xshare::model::MoeModel;
use xshare::runtime::{artifacts_root, Engine, Manifest};
use xshare::selection::PolicyKind;

fn main() -> Result<()> {
    let preset = "gptoss-mini";
    let manifest = Manifest::load(&artifacts_root().join(preset))?;
    let vocab = manifest.model.vocab;
    eprintln!("loading {preset} …");
    let mut model = MoeModel::new(Engine::load(manifest)?)?;

    // One request from each dataset — the paper's §6.3 construction.
    let gen = TraceGenerator::new(vocab, 3);
    let requests: Vec<Request> = gen
        .mixed_batch()
        .into_iter()
        .map(|t| {
            let mut prompt = t.prompt;
            prompt.truncate(10);
            let mut r = Request::new(t.id, prompt, 10);
            r.domain = t.domain;
            r
        })
        .collect();
    println!("mixed batch domains: {:?}", requests.iter().map(|r| r.domain.clone()).collect::<Vec<_>>());

    let cfg = ServeConfig {
        preset: preset.into(),
        batch_size: 4,
        spec_len: 3,
        ..Default::default()
    };

    println!("== mixed-dataset speculative batch (BS=4, L_s=3) ==");
    let mut base_outputs = None;
    for policy in ["vanilla", "spec:1:0:4", "spec:1:0:5", "spec:2:0:4", "batch:24:1"] {
        let mut c = cfg.clone();
        c.policy = PolicyKind::parse(policy).map_err(anyhow::Error::msg)?;
        let report = Scheduler::new(&mut model, c)?.run(requests.clone())?;
        let m = &report.metrics;
        let fid = match &base_outputs {
            None => {
                base_outputs = Some(report.outputs.clone());
                1.0
            }
            Some(b) => compare(b, &report.outputs).token_match,
        };
        println!(
            "{policy:<12} otps={:7.1}  activated/layer={:6.1}  fidelity={:5.1}%",
            m.otps(),
            m.mean_activated(),
            fid * 100.0
        );
    }
    println!("\nPer-request selection stays robust across domains: each request's");
    println!("budget covers its own experts, so no dataset starves another.");
    Ok(())
}
