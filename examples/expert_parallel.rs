//! Expert-parallel demo (§5 / Table 2 of the paper): dsr1-mini
//! (DeepSeek-R1 geometry: 256 experts, top-8, 1 shared) partitioned over
//! G=8 GPU groups. Compares vanilla routing against GPU-aware selection
//! (Algorithm 6, the paper's (k0=1, m_g=5) configuration) on activated
//! experts and peak per-GPU load.
//!
//!   make artifacts && cargo run --release --example expert_parallel

use anyhow::Result;

use xshare::config::{EpConfig, ServeConfig};
use xshare::coordinator::{compare, Request, Scheduler};
use xshare::ep::PlacementKind;
use xshare::gen::{TraceDomain, TraceGenerator};
use xshare::model::MoeModel;
use xshare::runtime::{artifacts_root, Engine, Manifest};
use xshare::selection::PolicyKind;

fn main() -> Result<()> {
    let preset = "dsr1-mini";
    let manifest = Manifest::load(&artifacts_root().join(preset))?;
    let vocab = manifest.model.vocab;
    eprintln!("loading {preset} …");
    let mut model = MoeModel::new(Engine::load(manifest)?)?;

    let trace = TraceGenerator::new(vocab, 11).generate(&TraceDomain::standard_suite(), 16);
    let requests: Vec<Request> = trace
        .into_iter()
        .map(|t| {
            let mut prompt = t.prompt;
            prompt.truncate(8);
            let mut r = Request::new(t.id, prompt, 8);
            r.domain = t.domain;
            r
        })
        .collect();

    let cfg = ServeConfig {
        preset: preset.into(),
        batch_size: 16,
        ep: Some(EpConfig { n_gpus: 8, placement: PlacementKind::Contiguous }),
        ..Default::default()
    };

    println!("== expert parallelism, G=8, BS=16, N=256 top-8 ==");
    let mut base_outputs = None;
    for policy in ["vanilla", "gpu:1:5", "gpu:1:3"] {
        let mut c = cfg.clone();
        c.policy = PolicyKind::parse(policy).map_err(anyhow::Error::msg)?;
        let report = Scheduler::new(&mut model, c)?.run(requests.clone())?;
        let m = &report.metrics;
        let fid = match &base_outputs {
            None => {
                base_outputs = Some(report.outputs.clone());
                1.0
            }
            Some(b) => compare(b, &report.outputs).token_match,
        };
        println!(
            "{policy:<10} activated/layer={:6.1}  max/GPU={:5.2}  fidelity={:5.1}%  sim-otps={:7.1}",
            m.mean_activated(),
            m.max_gpu_load.mean(),
            fid * 100.0,
            m.otps()
        );
    }
    println!("\nAlgorithm 6 bounds per-GPU load by construction (round-robin greedy");
    println!("across GPU groups) — the straggler GPU stops dominating layer latency.");
    Ok(())
}
