//! END-TO-END DRIVER (DESIGN.md §7): boot the TCP server on the real
//! gptoss-mini model (GPT-OSS-120B geometry: 128 experts, top-4), replay a
//! mixed five-dataset workload through concurrent clients, and report
//! latency / throughput / expert activation — once with vanilla routing and
//! once with XShare Algorithm 2 — plus the behavioural fidelity between the
//! two. The run recorded in EXPERIMENTS.md §E2E comes from this binary.
//!
//! Client starts are staggered a few milliseconds apart, so under the
//! stepped worker (continuous batching) late requests join the running
//! batch mid-flight instead of waiting for it to drain — the arrival
//! pattern the paper's deployment setting assumes.
//!
//!   make artifacts && cargo run --release --example serve_e2e

use std::time::Instant;

use anyhow::Result;

use xshare::config::ServeConfig;
use xshare::coordinator::{compare, Request};
use xshare::gen::{TraceDomain, TraceGenerator};
use xshare::runtime::artifacts_root;
use xshare::selection::PolicyKind;
use xshare::server::{Client, Server};

const PRESET: &str = "gptoss-mini";
const N_REQUESTS: usize = 16;
const MAX_NEW: usize = 12;

fn replay(policy: &str) -> Result<(std::collections::BTreeMap<u64, Vec<u32>>, f64, f64)> {
    let cfg = ServeConfig {
        preset: PRESET.into(),
        policy: PolicyKind::parse(policy).map_err(anyhow::Error::msg)?,
        batch_size: 16,
        addr: "127.0.0.1:0".into(),
        max_new_tokens: MAX_NEW,
        ..Default::default()
    };
    eprintln!("[{policy}] loading model + compiling artifacts …");
    let server = Server::start_from_dir(artifacts_root().join(PRESET), cfg)?;
    let addr = server.addr;

    let trace = TraceGenerator::new(512, 42).generate(&TraceDomain::standard_suite(), N_REQUESTS);
    let t0 = Instant::now();
    let handles: Vec<_> = trace
        .into_iter()
        .enumerate()
        .map(|(i, t)| {
            std::thread::spawn(move || -> Result<(u64, Vec<u32>, f64)> {
                // Staggered arrivals: exercise mid-flight admission rather
                // than one synchronized burst.
                std::thread::sleep(std::time::Duration::from_millis(4 * i as u64));
                let mut client = Client::connect(&addr)?;
                let mut prompt = t.prompt;
                prompt.truncate(12);
                let mut req = Request::new(t.id, prompt, MAX_NEW);
                req.domain = t.domain;
                let t_req = Instant::now();
                let resp = client.generate(&req)?;
                Ok((resp.id, resp.tokens, t_req.elapsed().as_secs_f64()))
            })
        })
        .collect();

    let mut outputs = std::collections::BTreeMap::new();
    let mut latencies = Vec::new();
    for h in handles {
        let (id, tokens, lat) = h.join().unwrap()?;
        outputs.insert(id, tokens);
        latencies.push(lat);
    }
    let wall = t0.elapsed().as_secs_f64();
    latencies.sort_by(f64::total_cmp);
    let p50 = latencies[latencies.len() / 2];
    let tokens: usize = outputs.values().map(Vec::len).sum();
    println!(
        "[{policy:<12}] {} requests, {} tokens, wall {:.2}s, wall-throughput {:.1} tok/s, p50 latency {:.2}s",
        outputs.len(),
        tokens,
        wall,
        tokens as f64 / wall,
        p50
    );
    server.shutdown();
    Ok((outputs, wall, p50))
}

fn main() -> Result<()> {
    println!("== XShare end-to-end serving driver ({PRESET}, BS=16, {N_REQUESTS} requests) ==");
    let (base_out, base_wall, _) = replay("vanilla")?;
    let (xs_out, xs_wall, _) = replay("batch:24:1")?;

    let f = compare(&base_out, &xs_out);
    println!("\n== comparison (vanilla vs batch:24:1) ==");
    println!("(note: under continuous batching the per-step batch composition");
    println!(" depends on arrival timing, so XShare outputs — and this fidelity");
    println!(" number — vary slightly between runs; the deterministic fidelity");
    println!(" figures come from the offline harness: cargo bench fig4/table1.)");
    println!("token match         : {:.2}%", f.token_match * 100.0);
    println!("exact requests      : {:.0}%", f.exact_requests * 100.0);
    println!("wall speed ratio    : {:.2}x (CPU emulation; see memsim OTPS in benches)", base_wall / xs_wall);
    println!("\n(Memory-bound OTPS effects are reported by `cargo bench` —");
    println!(" fig4_tradeoff / fig7 regenerate the paper's figures with the");
    println!(" H100 cost model; this driver proves the full serving stack");
    println!(" composes: TCP front-end → batcher → selection → PJRT model.)");
    Ok(())
}
