//! Quickstart: load the tiny preset, serve a small trace offline with
//! vanilla routing and with XShare's batch-aware selection (Algorithm 2),
//! and compare activated experts / simulated OTPS / output fidelity.
//!
//!   make artifacts && cargo run --release --example quickstart

use anyhow::Result;

use xshare::config::ServeConfig;
use xshare::coordinator::{compare, Request, Scheduler};
use xshare::gen::{TraceDomain, TraceGenerator};
use xshare::model::MoeModel;
use xshare::runtime::{artifacts_root, Engine, Manifest};
use xshare::selection::PolicyKind;

fn main() -> Result<()> {
    let preset = "tiny";
    let manifest = Manifest::load(&artifacts_root().join(preset))?;
    let vocab = manifest.model.vocab;
    let mut model = MoeModel::new(Engine::load(manifest)?)?;
    println!("loaded preset '{preset}' ({} experts, top-{})",
        model.dims().n_experts, model.dims().top_k);

    // A small trace over the synthetic evaluation domains.
    let trace = TraceGenerator::new(vocab, 42).generate(&TraceDomain::standard_suite(), 8);
    let requests: Vec<Request> = trace
        .into_iter()
        .map(|t| {
            let mut r = Request::new(t.id, t.prompt, 8);
            r.domain = t.domain;
            r
        })
        .collect();

    let mut cfg = ServeConfig {
        preset: preset.into(),
        batch_size: 4,
        ..Default::default()
    };

    // Baseline: vanilla top-k routing.
    let base = Scheduler::new(&mut model, cfg.clone())?.run(requests.clone())?;
    println!(
        "vanilla      : otps={:8.1}  activated/layer={:5.2}  tokens={}",
        base.metrics.otps(),
        base.metrics.mean_activated(),
        base.metrics.tokens_out
    );

    // XShare Algorithm 2: warm-up top-1 per token + greedy budget 2.
    cfg.policy = PolicyKind::parse("batch:2:1").unwrap();
    let xs = Scheduler::new(&mut model, cfg)?.run(requests)?;
    let fidelity = compare(&base.outputs, &xs.outputs);
    println!(
        "batch:2:1    : otps={:8.1}  activated/layer={:5.2}  tokens={}",
        xs.metrics.otps(),
        xs.metrics.mean_activated(),
        xs.metrics.tokens_out
    );
    println!(
        "fidelity     : token match {:.1}%  ({} requests compared)",
        fidelity.token_match * 100.0,
        fidelity.n_requests
    );
    println!(
        "expert saving: {:.1}% fewer activated experts, {:+.1}% OTPS",
        (1.0 - xs.metrics.mean_activated() / base.metrics.mean_activated()) * 100.0,
        (xs.metrics.otps() / base.metrics.otps() - 1.0) * 100.0
    );
    Ok(())
}
